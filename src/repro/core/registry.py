"""Shared registry machinery for named, discoverable components.

Three subsystems keep a name -> implementation table with the same
behaviours: self-registration via a decorator, did-you-mean lookup errors,
a single optional default listed first, and lazy import of the defining
module so enumeration works no matter which side was imported first.  They
used to be three copy-pasted implementations (``api/registry.py`` for
strategies, ``dataplane/registry.py`` for codecs, ``workload`` for traces);
this module is the one implementation they now share.

The public modules keep their existing names and error types
(``UnknownStrategyError``, ``UnknownCodecError``, ``UnknownTraceError``) --
those are thin subclasses of :class:`UnknownNameError` that preserve each
registry's historical message format, so callers and tests are unaffected.
"""

from __future__ import annotations

import difflib
from typing import Callable, Iterable


def suggest(name: str, known: Iterable[str], *, n: int = 3,
            cutoff: float = 0.4) -> tuple[str, ...]:
    """Close matches for a misspelled name (the did-you-mean candidates)."""
    return tuple(difflib.get_close_matches(name, list(known), n=n, cutoff=cutoff))


def unknown_message(subject: str, name: str, known: Iterable[str],
                    suggestions: tuple[str, ...], *,
                    style: str = "suffix") -> str:
    """Render an unknown-name message in one of the two historical formats.

    ``suffix``  -- "unknown codec 'x'; registered: a, b (did you mean 'a'?)"
    ``inline``  -- "unknown trace 'x' -- did you mean 'a'? (registered: a, b)"
    """
    known = list(known)
    if style == "inline":
        hint = (f" -- did you mean {', '.join(repr(c) for c in suggestions)}?"
                if suggestions else "")
        return f"unknown {subject} {name!r}{hint} (registered: {', '.join(known)})"
    msg = f"unknown {subject} {name!r}; registered: {', '.join(known)}"
    if suggestions:
        msg += f" (did you mean {' or '.join(map(repr, suggestions))}?)"
    return msg


class UnknownNameError(KeyError):
    """Base for registry lookup failures; carries name/known/suggestions."""

    def __init__(self, msg: str, *, name: str, known: Iterable[str],
                 suggestions: tuple[str, ...]):
        super().__init__(msg)
        self.name = name
        self.known = tuple(known)
        self.suggestions = suggestions

    def __str__(self) -> str:  # KeyError.__str__ repr-quotes; keep it readable
        return self.args[0]


class Registry:
    """One name -> value table with defaults, lazy imports, and rich errors.

    Parameters
    ----------
    subject:
        Human-readable noun for messages ("codec", "partitioner strategy").
    ensure:
        Zero-arg callable that imports the module(s) whose decorators
        populate this registry; invoked lazily before every read.
    error:
        ``(name, known) -> Exception`` factory for unknown-name lookups.
        Defaults to a plain :class:`UnknownNameError` in ``suffix`` style.
    allow_overwrite:
        When False (the default), re-registering a name raises ``ValueError``
        ("duplicate ..."); the trace registry historically allows overwrite.
    """

    def __init__(self, subject: str, *,
                 ensure: Callable[[], None] | None = None,
                 error: Callable[[str, tuple[str, ...]], Exception] | None = None,
                 allow_overwrite: bool = False):
        self.subject = subject
        self._ensure = ensure
        self._error = error
        self._allow_overwrite = allow_overwrite
        self._items: dict[str, object] = {}
        self._default: str | None = None

    # -- writes ------------------------------------------------------------

    def register(self, name: str, value, *, default: bool = False):
        if name in self._items and not self._allow_overwrite:
            raise ValueError(f"duplicate {self.subject} {name!r}")
        self._items[name] = value
        if default:
            if self._default is not None and self._default != name:
                raise ValueError(
                    f"conflicting defaults for {self.subject}: "
                    f"{self._default!r}, {name!r}")
            self._default = name
        return value

    # -- reads -------------------------------------------------------------

    def ensure(self) -> None:
        """Run the lazy-import hook (idempotent: imports cache themselves)."""
        if self._ensure is not None:
            self._ensure()

    def get(self, name: str):
        self.ensure()
        try:
            return self._items[name]
        except KeyError:
            known = self.names()
            if self._error is not None:
                raise self._error(name, known) from None
            raise UnknownNameError(
                unknown_message(self.subject, name, known, suggest(name, known)),
                name=name, known=known, suggestions=suggest(name, known),
            ) from None

    def names(self) -> tuple[str, ...]:
        """Registered names, sorted (the default, if any, first)."""
        self.ensure()
        names = sorted(self._items)
        if self._default in names:
            names.remove(self._default)
            names.insert(0, self._default)
        return tuple(names)

    def default(self) -> str | None:
        """The name used when a spec leaves the field unset."""
        self.ensure()
        return self._default

    def __contains__(self, name: str) -> bool:
        self.ensure()
        return name in self._items
