"""Joint partition + placement optimization (SEIFER Sec. 4, future work #3).

The paper's pipeline optimizes partitioning and placement *sequentially*:
first min-cut partitions, then bottleneck placement.  This module implements
the joint strategy the paper proposes to compare against: enumerate the
Pareto frontier of partitions (each distinct max-cut threshold yields a
different partition count / boundary profile), solve placement for each, and
keep the best end-to-end bottleneck.  Because fewer partitions means fewer
(possibly slow) links but larger per-node memory, neither extreme dominates
-- the joint search closes the gap, and `benchmarks/joint_opt.py` quantifies
it against the sequential baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.api.registry import register_strategy
from repro.core.graph import LayerGraph
from repro.core.partitioner import (
    PartitionResult,
    partition_exact_k,
    partition_min_bottleneck,
)
from repro.core.placement import CommGraph, PlacementResult, place_color_coding


@dataclasses.dataclass(frozen=True)
class JointResult:
    partition: PartitionResult
    placement: PlacementResult

    @property
    def feasible(self) -> bool:
        return self.partition.feasible and self.placement.feasible

    @property
    def bottleneck_latency(self) -> float:
        return self.placement.bottleneck_latency if self.feasible else float("inf")


@register_strategy(
    "joint", "sequential", default=True,
    description="paper's pipeline: min-bottleneck partition, then placement",
)
def sequential(
    graph: LayerGraph,
    comm: CommGraph,
    capacity: int,
    n_classes: int | None = 4,
    seed: int = 0,
    include_dispatcher: bool = False,
    dispatcher: int | None = None,
    max_parts: int | None = None,
) -> JointResult:
    """The paper's pipeline: min-bottleneck partition, then placement.

    ``max_parts`` caps the part count (callers exclude non-hosting nodes,
    e.g. the dispatcher); ``None`` allows up to one part per node.
    """
    if max_parts is None:
        max_parts = comm.n
    part = partition_min_bottleneck(graph, capacity, max_parts=max_parts)
    if not part.feasible:
        return JointResult(part, PlacementResult(False, (), float("inf"), "n/a"))
    place = place_color_coding(
        part.boundaries,
        [p.param_bytes for p in part.partitions],
        comm,
        n_classes=n_classes,
        seed=seed,
        in_bytes=graph.in_bytes if include_dispatcher else 0.0,
        out_bytes=graph.layers[-1].out_bytes if include_dispatcher else 0.0,
        dispatcher=dispatcher,
    )
    return JointResult(part, place)


@register_strategy(
    "joint", "joint",
    description="joint search over the partition-count frontier (future work #3)",
)
def joint(
    graph: LayerGraph,
    comm: CommGraph,
    capacity: int,
    n_classes: int | None = 4,
    seed: int = 0,
    include_dispatcher: bool = False,
    dispatcher: int | None = None,
    max_candidates: int | None = None,
    max_parts: int | None = None,
) -> JointResult:
    """Joint search over the partition-count frontier.

    For each feasible part count k in [k_min, max_parts], compute the exact-k
    min-max-cut partition, place it, and keep the lowest true bottleneck.
    """
    if max_parts is None:
        max_parts = comm.n
    base = partition_min_bottleneck(graph, capacity, max_parts=max_parts)
    if not base.feasible:
        return JointResult(base, PlacementResult(False, (), float("inf"), "n/a"))
    k_min = base.n_parts
    ks: Sequence[int] = range(k_min, max_parts + 1)
    if max_candidates is not None:
        ks = list(ks)[:max_candidates]
    # the sequential solution is always on the frontier: joint can only improve
    seq = sequential(graph, comm, capacity, n_classes=n_classes, seed=seed,
                     include_dispatcher=include_dispatcher, dispatcher=dispatcher,
                     max_parts=max_parts)
    best: JointResult | None = seq if seq.feasible else None
    for k in ks:
        part = partition_exact_k(graph, capacity, k)
        if not part.feasible:
            continue
        place = place_color_coding(
            part.boundaries,
            [p.param_bytes for p in part.partitions],
            comm,
            n_classes=n_classes,
            seed=seed,
            in_bytes=graph.in_bytes if include_dispatcher else 0.0,
            out_bytes=graph.layers[-1].out_bytes if include_dispatcher else 0.0,
            dispatcher=dispatcher,
        )
        if not place.feasible:
            continue
        cand = JointResult(part, place)
        if best is None or cand.bottleneck_latency < best.bottleneck_latency:
            best = cand
    if best is None:
        return JointResult(base, PlacementResult(False, (), float("inf"), "n/a"))
    return best
