"""Execution knob: which compute path the deployed executors and codecs run.

One frozen value threaded from ``DeploymentSpec`` through ``deploy()`` down
to the stage executors (``core.model_zoo``), the gpipe send/recv path
(``runtime.pipeline.make_gpipe``), and the per-link codecs
(``dataplane.codecs.Int8Codec``):

- ``use_pallas=False`` (default): pure-jnp reference paths -- what the
  planner's dry-run lowers and what CPU-only CI runs fastest.
- ``use_pallas=True, interpret=True``: the Pallas TPU kernels executed by
  the Pallas interpreter -- numerically the deployment artifact, runnable
  on CPU.  This is the CI fast-path leg.
- ``use_pallas=True, interpret=False``: the compiled TPU kernels.

Lives in ``core`` (no jax imports) so every layer can depend on it.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ExecutionKnob:
    use_pallas: bool = False
    interpret: bool = False

    def kwargs(self) -> dict:
        """The kwargs every kernel entry point accepts, ready to splat."""
        return {"use_pallas": self.use_pallas, "interpret": self.interpret}


REF = ExecutionKnob()
PALLAS_INTERPRET = ExecutionKnob(use_pallas=True, interpret=True)
