"""Partition placement on a communication graph (SEIFER Sec. 2.2-1c).

"Place the partitions such that the ones which transfer the most data are
placed on the highest bandwidth edges in the communication graph."

Formally: given k partitions with boundary weights w_0..w_{k-2} (bytes) and a
node graph with link bandwidths, find an injective node path p_0..p_{k-1}
minimizing  max_i  w_i / bw(p_i, p_{i+1}),  subject to node capacities.
This is a minimum-bottleneck k-path problem (NP-hard in general); per the
paper's acknowledgements we use the Alon-Yuster-Zwick *color-coding* k-path
algorithm on a *bandwidth-class*-quantized graph, with binary search over the
finite set of candidate bottleneck latencies.  For small clusters an exact
subset-DP is used (and doubles as the oracle in tests / the approximation-
ratio benchmark).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Sequence

import numpy as np

from repro.api.registry import register_strategy

EXACT_NODE_LIMIT = 16  # subset DP up to 2^16 states (vectorized per level)

# flat color-coding binary search above this many nodes is replaced by the
# hierarchical coarsen -> k-path -> refine pipeline (near-linear in the
# comm-matrix size instead of superlinear in n)
HIERARCHICAL_NODE_LIMIT = 64


# ---------------------------------------------------------------------------
# Communication graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CommGraph:
    """Symmetric link-bandwidth matrix (bytes/s; 0 = no link) + capacities."""

    bw: np.ndarray  # (n, n) float
    node_capacity: np.ndarray  # (n,) float bytes

    def __post_init__(self) -> None:
        bw = np.asarray(self.bw, dtype=float)
        if bw.ndim != 2 or bw.shape[0] != bw.shape[1]:
            raise ValueError("bw must be square")
        if not np.allclose(bw, bw.T):
            raise ValueError("bw must be symmetric")
        if np.any(bw < 0):
            raise ValueError("bw must be nonnegative")
        object.__setattr__(self, "bw", bw)
        cap = np.asarray(self.node_capacity, dtype=float)
        if cap.shape != (bw.shape[0],):
            raise ValueError("node_capacity shape mismatch")
        object.__setattr__(self, "node_capacity", cap)

    @property
    def n(self) -> int:
        return self.bw.shape[0]

    def key(self) -> int:
        """Content digest for planner-cache keying (computed once: the
        matrices are frozen, so the digest can be memoized on the instance)."""
        k = getattr(self, "_key", None)
        if k is None:
            k = hash((self.bw.tobytes(), self.node_capacity.tobytes()))
            object.__setattr__(self, "_key", k)
        return k

    @staticmethod
    def uniform(bw: np.ndarray, capacity: float) -> "CommGraph":
        n = np.asarray(bw).shape[0]
        return CommGraph(bw=np.asarray(bw, float), node_capacity=np.full(n, float(capacity)))


def quantize_bandwidths(
    bw: np.ndarray, n_classes: int | None, scheme: str = "quantile"
) -> tuple[np.ndarray, np.ndarray]:
    """Discretize link bandwidths into ``n_classes`` classes (paper's knob).

    Each positive edge is replaced by the *floor* of its class (conservative:
    the algorithm never assumes more bandwidth than the link has).  With
    ``n_classes=None`` the graph is returned unquantized (infinite classes).
    Returns (quantized bw matrix, ascending class floor values).
    """
    bw = np.asarray(bw, dtype=float)
    pos = bw[bw > 0]
    if n_classes is None or pos.size == 0:
        vals = np.unique(pos) if pos.size else np.array([])
        return bw.copy(), vals
    n_classes = max(1, int(n_classes))
    lo, hi = pos.min(), pos.max()
    if scheme == "quantile":
        qs = np.quantile(pos, np.linspace(0.0, 1.0, n_classes + 1))
    elif scheme == "geometric":
        qs = np.geomspace(lo, hi, n_classes + 1) if lo > 0 else np.linspace(lo, hi, n_classes + 1)
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    floors = qs[:-1]
    # map each edge to the floor of its bucket
    idx = np.clip(np.searchsorted(qs, bw, side="right") - 1, 0, n_classes - 1)
    out = np.where(bw > 0, floors[idx], 0.0)
    return out, np.unique(floors)


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacementResult:
    feasible: bool
    path: tuple[int, ...]
    bottleneck_latency: float  # on the TRUE (unquantized) bandwidths
    algorithm: str
    trials_used: int = 0

    @property
    def throughput(self) -> float:
        if not self.feasible:
            return 0.0
        return float("inf") if self.bottleneck_latency == 0 else 1.0 / self.bottleneck_latency


def _true_bottleneck(
    boundaries: Sequence[float],
    path: Sequence[int],
    comm: CommGraph,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
) -> float:
    lat = 0.0
    for i, w in enumerate(boundaries):
        b = comm.bw[path[i], path[i + 1]]
        lat = max(lat, np.inf if b <= 0 else w / b)
    if dispatcher is not None:
        if in_bytes > 0:
            b = comm.bw[dispatcher, path[0]]
            lat = max(lat, np.inf if b <= 0 else in_bytes / b)
        if out_bytes > 0:
            b = comm.bw[path[-1], dispatcher]
            lat = max(lat, np.inf if b <= 0 else out_bytes / b)
    return lat


def _infeasible(algo: str, trials_used: int = 0) -> PlacementResult:
    return PlacementResult(False, (), float("inf"), algo, trials_used)


# ---------------------------------------------------------------------------
# Exact subset DP (minimax) -- oracle + small-n fast path
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _subset_tables(n: int) -> tuple[np.ndarray, tuple[np.ndarray, ...]]:
    """Memoized ``(popcount, subsets_by_popcount)`` tables over ``2^n`` states.

    Both the exact subset DP and the color-coding DP rebuild these on every
    call otherwise -- and ``replicas="auto"`` calls the DP R times per plan,
    so the tables dominated small-cluster planning time.  ``n <=
    EXACT_NODE_LIMIT`` (or k for color coding), so the cache stays tiny.
    """
    nstates = 1 << n
    popcount = np.array([bin(s).count("1") for s in range(nstates)], dtype=np.int32)
    subsets_by_pc = tuple(np.flatnonzero(popcount == p) for p in range(n + 1))
    return popcount, subsets_by_pc


def _exact_minimax_path(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    bwq: np.ndarray,
    cap: np.ndarray,
) -> tuple[float, list[int]] | None:
    """Subset DP: dp[S][v] = min bottleneck placing first |S| parts, end v.

    Vectorized per popcount level: O(2^n * n^2) flops but only O(n*k) python
    iterations, so the Fig.3 simulation sweep stays fast.  Exact on the given
    (possibly quantized) bandwidth matrix.
    """
    n = bwq.shape[0]
    k = len(part_bytes)
    if k > n:
        return None
    if k == 1:
        idx = np.flatnonzero(cap >= part_bytes[0])
        return (0.0, [int(idx[0])]) if idx.size else None
    INF = np.inf
    nstates = 1 << n
    dp = np.full((nstates, n), INF)
    # latency matrices per boundary position: lat[pos][v, u] = w/bw(v,u)
    with np.errstate(divide="ignore"):
        lat = [np.where(bwq > 0, w / np.maximum(bwq, 1e-300), INF) for w in boundaries]
        for L in lat:
            np.fill_diagonal(L, INF)
    ok0 = np.flatnonzero(cap >= part_bytes[0])
    if ok0.size == 0:
        return None
    dp[1 << ok0, ok0] = 0.0
    _, subsets_by_pc = _subset_tables(n)
    for p in range(1, k):
        Ss = subsets_by_pc[p]
        block = dp[Ss]  # (m, n)
        finite_rows = np.isfinite(block).any(axis=1)
        if not finite_rows.any():
            return None  # dead end: no placement of first p partitions
        Ss, block = Ss[finite_rows], block[finite_rows]
        pos = p - 1
        # cand[m, u] = min over v of max(block[m, v], lat[pos][v, u])
        cand = np.min(np.maximum(block[:, :, None], lat[pos][None, :, :]), axis=1)
        okc = cap >= part_bytes[p]
        for u in range(n):
            if not okc[u]:
                continue
            bit = 1 << u
            mask = (Ss & bit) == 0
            if not mask.any():
                continue
            np.minimum.at(dp, (Ss[mask] | bit, u), cand[mask, u])
    # best over |S| == k
    Sk = subsets_by_pc[k]
    vals = dp[Sk]
    flat = int(np.argmin(vals))
    best_state = int(Sk[flat // n])
    best_v = flat % n
    best_val = float(vals[flat // n, flat % n])
    if not np.isfinite(best_val):
        return None
    # reconstruct by walking equalities backwards (maxes are exact copies)
    path = [best_v]
    S, v, val = best_state, best_v, best_val
    for p in range(k - 1, 0, -1):
        Sp = S & ~(1 << v)
        found = False
        for u in range(n):
            if not (Sp >> u) & 1:
                continue
            step = max(dp[Sp, u], lat[p - 1][u, v])
            if step == val or (np.isfinite(step) and step <= val + 1e-18):
                S, v, val = Sp, u, float(dp[Sp, u])
                path.append(u)
                found = True
                break
        if not found:  # pragma: no cover - defensive
            return None
    path.reverse()
    return best_val, path


# ---------------------------------------------------------------------------
# Color-coding k-path feasibility (large n)
# ---------------------------------------------------------------------------

def _color_coding_feasible(
    feas: list[np.ndarray],  # per-position boolean edge feasibility (n, n)
    cap_ok: list[np.ndarray],  # per-position boolean node feasibility (n,)
    k: int,
    trials: int,
    rng: np.random.Generator,
) -> tuple[list[int] | None, int]:
    """Alon-Yuster-Zwick color coding: random k-colorings + color-subset DP.

    Returns ``(path, trials_used)`` -- a feasible path (list of k node ids)
    or None, plus the number of colorings actually drawn (1 on a first-trial
    hit; ``trials`` on failure).  Monte-Carlo: may miss a feasible path with
    probability <= (1 - k!/k^k)^trials.
    """
    if k == 1:
        idx = np.flatnonzero(cap_ok[0])
        return ([int(idx[0])] if idx.size else None), 0
    n = feas[0].shape[0]
    nstates = 1 << k
    popcount, _ = _subset_tables(k)
    order = np.argsort(popcount, kind="stable")
    for trial in range(trials):
        colors = rng.integers(0, k, size=n)
        color_bit = (1 << colors).astype(np.int64)
        dp = np.zeros((nstates, n), dtype=bool)
        parent = np.full((nstates, n), -1, dtype=np.int32)
        for v in range(n):
            if cap_ok[0][v]:
                dp[color_bit[v], v] = True
        found: tuple[int, int] | None = None
        for S in order:
            pc = popcount[S]
            if pc == 0 or pc >= k:
                continue
            row = dp[S]
            if not row.any():
                continue
            pos = pc - 1
            # reach[u] = any_v row[v] & feas[pos][v, u]
            reach = row @ feas[pos]  # bool matmul
            newmask = reach & cap_ok[pc] & ((color_bit & S) == 0)
            if not newmask.any():
                continue
            vs = np.flatnonzero(row)
            for u in np.flatnonzero(newmask):
                S2 = S | int(color_bit[u])
                if not dp[S2, u]:
                    dp[S2, u] = True
                    # any predecessor works; pick the first feasible
                    pred = vs[feas[pos][vs, u]][0]
                    parent[S2, u] = pred
                    if popcount[S2] == k:
                        found = (S2, u)
            if found:
                break
        if found:
            S, v = found
            path = [v]
            while parent[S, v] >= 0:
                u = int(parent[S, v])
                S &= ~int(1 << int(np.log2(int(color_bit[v]))))
                v = u
                path.append(v)
            path.reverse()
            return [int(x) for x in path], trial + 1
    return None, trials


# ---------------------------------------------------------------------------
# Color-coding binary search over candidate bottleneck latencies
# ---------------------------------------------------------------------------

def _search_color_coding(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    bwq: np.ndarray,
    class_vals: np.ndarray,
    cap: np.ndarray,
    trials: int,
    seed: int,
) -> tuple[list[int] | None, int]:
    """Binary search the finite candidate-latency lattice with Monte-Carlo
    color-coding feasibility checks.  Returns ``(path, trials_used)``.

    Each feasibility check draws its colorings from an RNG seeded by
    ``(seed, candidate_index)``, so whether level ``i`` is judged feasible is
    a pure function of the instance -- not of the order the binary search
    happened to visit levels through a shared RNG stream.  A color-coding
    *false negative* at ``mid`` would otherwise prune the lower (better)
    half outright, so after the search converges a confirmation pass spends
    a doubled trial budget one level below the found candidate (and keeps
    descending while that succeeds).
    """
    k = len(part_bytes)
    cands = sorted(
        {w / c for w in boundaries for c in class_vals if c > 0 and w > 0} | {0.0}
    )
    cap_ok = [cap >= pb for pb in part_bytes]

    def check(idx: int, n_trials: int) -> list[int] | None:
        nonlocal trials_used
        L = cands[idx]
        feas = [
            (bwq > 0) & (bwq * max(L, 1e-300) >= w) if w > 0 else (bwq > 0)
            for w in boundaries
        ]
        rng = np.random.default_rng((seed, idx))
        path, used = _color_coding_feasible(feas, cap_ok, k, n_trials, rng)
        trials_used += used
        return path

    lo, hi = 0, len(cands) - 1
    best_path: list[int] | None = None
    best_idx: int | None = None
    trials_used = 0
    while lo <= hi:
        mid = (lo + hi) // 2
        path = check(mid, trials)
        if path is not None:
            best_path, best_idx = path, mid
            hi = mid - 1
        else:
            lo = mid + 1
    # confirmation pass: a false negative during the search may have pruned
    # strictly better levels; re-try below the found candidate harder
    while best_idx is not None and best_idx > 0:
        path = check(best_idx - 1, 2 * trials)
        if path is None:
            break
        best_path, best_idx = path, best_idx - 1
    return best_path, trials_used


# ---------------------------------------------------------------------------
# Public placement algorithms
# ---------------------------------------------------------------------------

@register_strategy(
    "placer", "color_coding", default=True,
    description="paper's placer: bandwidth-class quantization + min-bottleneck "
                "k-path (exact subset DP small n, color coding mid n, "
                "hierarchical coarsen+refine large n)",
)
def place_color_coding(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
    n_classes: int | None = 4,
    trials: int = 60,
    seed: int = 0,
    exact_limit: int = EXACT_NODE_LIMIT,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
    hierarchical_limit: int | None = HIERARCHICAL_NODE_LIMIT,
    quantized: tuple[np.ndarray, np.ndarray] | None = None,
) -> PlacementResult:
    """SEIFER placement: bandwidth-class quantization + min-bottleneck k-path.

    Small clusters (n <= exact_limit) use the exact subset DP on the
    quantized graph; mid-size clusters binary-search the candidate
    bottleneck latencies with color-coding feasibility checks; clusters
    above ``hierarchical_limit`` nodes (``None`` disables) delegate to
    ``place_hierarchical`` -- coarsen into bandwidth-tiered groups, solve
    the k-path over groups, refine within the winning groups.  The reported
    bottleneck latency is always evaluated on the TRUE bandwidths of the
    found path.  ``quantized`` short-circuits ``quantize_bandwidths`` with
    a precomputed ``(bwq, class_vals)`` pair (the planner's cache).
    """
    algo = f"color_coding(c={n_classes})"
    k = len(part_bytes)
    if k == 0 or k > comm.n:
        return _infeasible(algo)
    if hierarchical_limit is not None and comm.n > hierarchical_limit:
        return place_hierarchical(
            boundaries, part_bytes, comm,
            n_classes=n_classes, trials=trials, seed=seed,
            exact_limit=exact_limit, in_bytes=in_bytes, out_bytes=out_bytes,
            dispatcher=dispatcher, quantized=quantized,
        )
    bwq, class_vals = (
        quantized if quantized is not None
        else quantize_bandwidths(comm.bw, n_classes)
    )
    cap = comm.node_capacity

    if comm.n <= exact_limit:
        res = _exact_minimax_path(boundaries, part_bytes, bwq, cap)
        if res is None:
            return _infeasible(algo)
        _, path = res
        lat = _true_bottleneck(boundaries, path, comm, in_bytes, out_bytes, dispatcher)
        return PlacementResult(True, tuple(path), float(lat), algo)

    best_path, trials_used = _search_color_coding(
        boundaries, part_bytes, bwq, class_vals, cap, trials, seed)
    if best_path is None:
        return _infeasible(algo, trials_used)
    lat = _true_bottleneck(boundaries, best_path, comm, in_bytes, out_bytes, dispatcher)
    return PlacementResult(True, tuple(best_path), float(lat), algo, trials_used)


# ---------------------------------------------------------------------------
# Hierarchical large-n placement: coarsen -> group k-path -> refine
# ---------------------------------------------------------------------------

def _bandwidth_groups(
    bw: np.ndarray, hosting: Sequence[int], group_size: int
) -> list[list[int]]:
    """Cluster hosting nodes into bandwidth-tiered groups of <= group_size.

    Greedy: seed each group at the best-connected unassigned node (largest
    total bandwidth into the remaining hosting set), then attach its
    strongest unassigned neighbors.  One numpy pass per group, so the whole
    coarsening is near-linear in the comm-matrix size.
    """
    hosting = np.asarray(sorted(hosting), dtype=int)
    sub = bw[np.ix_(hosting, hosting)]
    unassigned = np.ones(len(hosting), dtype=bool)
    totals = sub.sum(axis=1)
    groups: list[list[int]] = []
    while unassigned.any():
        live = np.flatnonzero(unassigned)
        seed_local = live[int(np.argmax(totals[live]))]
        row = np.where(unassigned, sub[seed_local], -1.0)
        row[seed_local] = -1.0
        nbrs = np.argsort(-row, kind="stable")[: group_size - 1]
        members = [seed_local] + [int(u) for u in nbrs if row[u] > 0]
        unassigned[members] = False
        groups.append([int(hosting[m]) for m in members])
    return groups


def _coarse_group_path(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    groups: list[list[int]],
    bw: np.ndarray,
    cap: np.ndarray,
    in_bytes: float,
    out_bytes: float,
    dispatcher: int | None,
) -> list[int] | None:
    """Min-bottleneck k-path over group representatives.

    DP over (group, run-length) states: position ``p`` ends in group ``g``
    having placed the last ``c+1`` consecutive partitions there.  Staying
    inside a group is charged its median intra-group bandwidth; crossing to
    another group its best inter-group link (the refinement stage picks the
    actual members, so the aggregate is a planning estimate, not a claim).
    Returns one group index per partition position, or None when no
    capacity-feasible group sequence exists.
    """
    k = len(part_bytes)
    G = len(groups)
    gmax = max(len(g) for g in groups)
    INF = np.inf
    # aggregate bandwidths
    intra = np.zeros(G)
    for gi, g in enumerate(groups):
        block = bw[np.ix_(g, g)]
        pos_links = block[block > 0]
        intra[gi] = float(np.median(pos_links)) if pos_links.size else 0.0
    inter = np.zeros((G, G))
    for gi in range(G):
        for hj in range(gi + 1, G):
            m = float(bw[np.ix_(groups[gi], groups[hj])].max())
            inter[gi, hj] = inter[hj, gi] = m
    # cap_count[g, p] = members of g able to host partition p
    cap_count = np.array([
        [int(np.sum(cap[np.asarray(g)] >= pb)) for pb in part_bytes]
        for g in groups
    ])
    disp_bw = np.array([
        float(bw[dispatcher, g].max()) if dispatcher is not None else 0.0
        for g in groups
    ])

    def edge(w: float, rate: np.ndarray) -> np.ndarray:
        rate = np.asarray(rate, dtype=float)
        if w <= 0:
            return np.zeros_like(rate)
        return np.where(rate > 0, w / np.maximum(rate, 1e-300), INF)

    # dp[g, c]: bottleneck; wmin[g, c]: min cap_count over the current run
    dp = np.full((G, gmax), INF)
    wmin = np.zeros((G, gmax), dtype=int)
    start_lat = edge(in_bytes, disp_bw) if dispatcher is not None else np.zeros(G)
    feas0 = cap_count[:, 0] >= 1
    dp[feas0, 0] = start_lat[feas0]
    wmin[:, 0] = cap_count[:, 0]
    parents: list[np.ndarray] = []  # per position: (G, gmax, 2) parent state
    for p in range(1, k):
        w = float(boundaries[p - 1])
        inter_lat = edge(w, inter)
        np.fill_diagonal(inter_lat, INF)
        intra_lat = edge(w, intra)
        m = dp.min(axis=1)  # best run-length per group
        mc = dp.argmin(axis=1)
        # move into h from the best source group
        move_scores = np.maximum(m[:, None], inter_lat)  # (src g, dst h)
        move = move_scores.min(axis=0)
        move_src = move_scores.argmin(axis=0)
        new_dp = np.full((G, gmax), INF)
        new_wmin = np.zeros((G, gmax), dtype=int)
        parent = np.full((G, gmax, 2), -1, dtype=np.int32)
        ok_h = cap_count[:, p] >= 1
        new_dp[ok_h, 0] = move[ok_h]
        new_wmin[:, 0] = cap_count[:, p]
        parent[ok_h, 0, 0] = move_src[ok_h]
        parent[ok_h, 0, 1] = mc[move_src[ok_h]]
        # stay in g, run length c+1 (needs c+1 hostable members in the run)
        stay = np.maximum(dp[:, :-1], intra_lat[:, None])
        run_wmin = np.minimum(wmin[:, :-1], cap_count[:, p][:, None])
        run_len = np.arange(2, gmax + 1)[None, :]
        stay = np.where(run_wmin >= run_len, stay, INF)
        better = stay < new_dp[:, 1:]
        new_dp[:, 1:] = np.where(better, stay, new_dp[:, 1:])
        new_wmin[:, 1:] = np.where(better, run_wmin, new_wmin[:, 1:])
        gg = np.arange(G)[:, None].repeat(gmax - 1, axis=1)
        cc = np.arange(gmax - 1)[None, :].repeat(G, axis=0)
        parent[:, 1:, 0] = np.where(better, gg, parent[:, 1:, 0])
        parent[:, 1:, 1] = np.where(better, cc, parent[:, 1:, 1])
        dp, wmin = new_dp, new_wmin
        parents.append(parent)
    final = dp.copy()
    if dispatcher is not None and out_bytes > 0:
        final = np.maximum(final, np.asarray(edge(out_bytes, disp_bw))[:, None])
    if not np.isfinite(final.min()):
        return None
    flat = int(np.argmin(final))
    g, c = flat // gmax, flat % gmax
    seq = [g]
    for p in range(k - 1, 0, -1):
        g, c = (int(x) for x in parents[p - 1][g, c])
        if g < 0:  # pragma: no cover - defensive
            return None
        seq.append(g)
    seq.reverse()
    return seq


@register_strategy(
    "placer", "hierarchical",
    description="hierarchical large-n placer: bandwidth-tiered groups, "
                "coarse k-path over group representatives, refinement "
                "within the winning groups (near-linear in cluster size)",
)
def place_hierarchical(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
    n_classes: int | None = 4,
    trials: int = 60,
    seed: int = 0,
    exact_limit: int = EXACT_NODE_LIMIT,
    group_size: int | None = None,
    refine_limit: int | None = None,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
    quantized: tuple[np.ndarray, np.ndarray] | None = None,
) -> PlacementResult:
    """Hierarchical min-bottleneck placement for large clusters.

    Three stages, each bounded so total work is near-linear in the size of
    the comm matrix instead of superlinear in ``n``:

      1. **coarsen** -- cluster hosting nodes into bandwidth-tiered groups
         of <= ``group_size`` (default ``EXACT_NODE_LIMIT``),
      2. **coarse solve** -- min-bottleneck k-path over group
         representatives (DP over (group, run-length) states),
      3. **refine** -- re-solve exactly (or by flat color coding when the
         union exceeds ``exact_limit``) inside the union of the winning
         groups, trimmed to <= ``refine_limit`` nodes.

    Falls back to the flat full-graph color-coding search when the coarse
    stage or the refinement finds no feasible path, so it is never less
    complete than the flat algorithm -- only cheaper.
    """
    k = len(part_bytes)
    n = comm.n
    if group_size is None:
        group_size = EXACT_NODE_LIMIT
    if refine_limit is None:
        refine_limit = max(exact_limit, k + 4)
    algo = f"hierarchical(c={n_classes},g={group_size})"
    if k == 0 or k > n:
        return _infeasible(algo)
    cap = comm.node_capacity
    hosting = [
        i for i in range(n)
        if cap[i] >= min(part_bytes) and i != dispatcher and comm.bw[i].max() > 0
    ]
    if len(hosting) < k:
        return _infeasible(algo)

    def flat_fallback() -> PlacementResult:
        res = place_color_coding(
            boundaries, part_bytes, comm,
            n_classes=n_classes, trials=trials, seed=seed,
            exact_limit=exact_limit, in_bytes=in_bytes, out_bytes=out_bytes,
            dispatcher=dispatcher, hierarchical_limit=None, quantized=quantized,
        )
        return dataclasses.replace(res, algorithm=algo + "+flat_fallback")

    groups = _bandwidth_groups(comm.bw, hosting, group_size)
    if len(groups) <= 1:
        return flat_fallback()  # one tier: the flat solve IS the refinement
    seq = _coarse_group_path(
        boundaries, part_bytes, groups, comm.bw, cap,
        in_bytes, out_bytes, dispatcher,
    )
    if seq is None:
        return flat_fallback()

    # union of winning groups, trimmed to refine_limit by per-group quota
    chosen = sorted(set(seq), key=seq.index)
    positions = {g: sum(1 for s in seq if s == g) for g in chosen}
    union: list[int] = []
    budget = max(refine_limit, k)
    for g in chosen:
        quota = max(positions[g] + 1,
                    int(round(budget * positions[g] / k)))
        members = groups[g]
        if len(members) > quota:
            arr = np.asarray(members)
            conn = comm.bw[np.ix_(arr, arr)].sum(axis=1)
            members = [int(arr[i]) for i in np.argsort(-conn, kind="stable")[:quota]]
        union.extend(m for m in members if m not in union)
    union = union[: max(budget, k)]
    if len(union) < k:
        return flat_fallback()

    # refinement sub-cluster: winning members + the dispatcher (links only)
    sub_nodes = list(union)
    sub_disp = None
    if dispatcher is not None:
        sub_disp = len(sub_nodes)
        sub_nodes.append(dispatcher)
    idx = np.asarray(sub_nodes)
    sub_cap = cap[idx].copy()
    if sub_disp is not None:
        sub_cap[sub_disp] = min(float(sub_cap[sub_disp]), 0.0)
    sub = CommGraph(bw=comm.bw[np.ix_(idx, idx)], node_capacity=sub_cap)
    res = place_color_coding(
        boundaries, part_bytes, sub,
        n_classes=n_classes, trials=trials, seed=seed, exact_limit=exact_limit,
        in_bytes=in_bytes, out_bytes=out_bytes, dispatcher=sub_disp,
        hierarchical_limit=None,
    )
    if not res.feasible:
        return flat_fallback()
    path = tuple(int(idx[v]) for v in res.path)
    lat = _true_bottleneck(boundaries, path, comm, in_bytes, out_bytes, dispatcher)
    return PlacementResult(True, path, float(lat), algo, res.trials_used)


@register_strategy(
    "placer", "greedy",
    description="left-to-right greedy: always take the fastest feasible link",
)
def place_greedy(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
) -> PlacementResult:
    """Left-to-right greedy: from every start node, repeatedly take the
    highest-bandwidth feasible edge.  Cheap baseline (paper's 'edge
    matching' in its simplest form)."""
    algo = "greedy"
    k = len(part_bytes)
    n = comm.n
    if k == 0 or k > n:
        return _infeasible(algo)
    best: tuple[float, list[int]] | None = None
    cap_ok = [comm.node_capacity >= pb for pb in part_bytes]
    for start in range(n):
        if not cap_ok[0][start]:
            continue
        path = [start]
        avail = np.ones(n, dtype=bool)
        avail[start] = False
        ok = True
        for pos in range(k - 1):
            cand_bw = np.where(avail & cap_ok[pos + 1], comm.bw[path[-1]], -1.0)
            u = int(np.argmax(cand_bw))
            if cand_bw[u] <= 0:
                ok = False
                break
            path.append(u)
            avail[u] = False
        if not ok:
            continue
        lat = _true_bottleneck(boundaries, path, comm, in_bytes, out_bytes, dispatcher)
        if best is None or lat < best[0]:
            best = (lat, path)
    if best is None:
        return _infeasible(algo)
    return PlacementResult(True, tuple(best[1]), float(best[0]), algo)


@register_strategy(
    "placer", "random",
    description="random feasible path -- the no-algorithm baseline",
)
def place_random(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
    seed: int = 0,
    attempts: int = 20,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
) -> PlacementResult:
    """Random feasible path -- the no-algorithm baseline."""
    algo = "random"
    rng = np.random.default_rng(seed)
    k = len(part_bytes)
    n = comm.n
    if k == 0 or k > n:
        return _infeasible(algo)
    for _ in range(attempts):
        perm = rng.permutation(n)[:k]
        if any(comm.node_capacity[perm[j]] < part_bytes[j] for j in range(k)):
            continue
        if any(comm.bw[perm[i], perm[i + 1]] <= 0 for i in range(k - 1)):
            continue
        lat = _true_bottleneck(boundaries, list(perm), comm, in_bytes, out_bytes, dispatcher)
        return PlacementResult(True, tuple(int(x) for x in perm), float(lat), algo)
    return _infeasible(algo)


@register_strategy(
    "placer", "optimal",
    description="exact optimum on TRUE bandwidths (subset DP, n <= 16)",
)
def place_optimal(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
) -> PlacementResult:
    """Exact optimum on the TRUE bandwidths (subset DP).

    Limited to ``n <= EXACT_NODE_LIMIT`` (16) -- the guard below enforces
    exactly that bound.  Used for the approximation-ratio benchmark (paper
    Sec. 4, item 2) and as the refinement oracle inside
    ``place_hierarchical``.
    """
    algo = "optimal"
    if comm.n > EXACT_NODE_LIMIT:
        raise ValueError(f"place_optimal limited to n <= {EXACT_NODE_LIMIT}")
    k = len(part_bytes)
    if k == 0 or k > comm.n:
        return _infeasible(algo)
    res = _exact_minimax_path(boundaries, part_bytes, comm.bw, comm.node_capacity)
    if res is None:
        return _infeasible(algo)
    _, path = res
    lat = _true_bottleneck(boundaries, path, comm, in_bytes, out_bytes, dispatcher)
    return PlacementResult(True, tuple(path), float(lat), algo)


def place_brute_force(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
) -> PlacementResult:
    """Permutation brute force (n <= 8) -- test oracle for place_optimal."""
    algo = "brute_force"
    n, k = comm.n, len(part_bytes)
    if n > 8:
        raise ValueError("brute force limited to n <= 8")
    if k == 0 or k > n:
        return _infeasible(algo)
    best: tuple[float, tuple[int, ...]] | None = None
    for perm in itertools.permutations(range(n), k):
        if any(comm.node_capacity[perm[j]] < part_bytes[j] for j in range(k)):
            continue
        lat = _true_bottleneck(boundaries, perm, comm)
        if np.isfinite(lat) and (best is None or lat < best[0]):
            best = (lat, perm)
    if best is None:
        return _infeasible(algo)
    return PlacementResult(True, best[1], float(best[0]), algo)
