"""Partition placement on a communication graph (SEIFER Sec. 2.2-1c).

"Place the partitions such that the ones which transfer the most data are
placed on the highest bandwidth edges in the communication graph."

Formally: given k partitions with boundary weights w_0..w_{k-2} (bytes) and a
node graph with link bandwidths, find an injective node path p_0..p_{k-1}
minimizing  max_i  w_i / bw(p_i, p_{i+1}),  subject to node capacities.
This is a minimum-bottleneck k-path problem (NP-hard in general); per the
paper's acknowledgements we use the Alon-Yuster-Zwick *color-coding* k-path
algorithm on a *bandwidth-class*-quantized graph, with binary search over the
finite set of candidate bottleneck latencies.  For small clusters an exact
subset-DP is used (and doubles as the oracle in tests / the approximation-
ratio benchmark).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

from repro.api.registry import register_strategy

EXACT_NODE_LIMIT = 16  # subset DP up to 2^16 states (vectorized per level)


# ---------------------------------------------------------------------------
# Communication graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CommGraph:
    """Symmetric link-bandwidth matrix (bytes/s; 0 = no link) + capacities."""

    bw: np.ndarray  # (n, n) float
    node_capacity: np.ndarray  # (n,) float bytes

    def __post_init__(self) -> None:
        bw = np.asarray(self.bw, dtype=float)
        if bw.ndim != 2 or bw.shape[0] != bw.shape[1]:
            raise ValueError("bw must be square")
        if not np.allclose(bw, bw.T):
            raise ValueError("bw must be symmetric")
        if np.any(bw < 0):
            raise ValueError("bw must be nonnegative")
        object.__setattr__(self, "bw", bw)
        cap = np.asarray(self.node_capacity, dtype=float)
        if cap.shape != (bw.shape[0],):
            raise ValueError("node_capacity shape mismatch")
        object.__setattr__(self, "node_capacity", cap)

    @property
    def n(self) -> int:
        return self.bw.shape[0]

    @staticmethod
    def uniform(bw: np.ndarray, capacity: float) -> "CommGraph":
        n = np.asarray(bw).shape[0]
        return CommGraph(bw=np.asarray(bw, float), node_capacity=np.full(n, float(capacity)))


def quantize_bandwidths(
    bw: np.ndarray, n_classes: int | None, scheme: str = "quantile"
) -> tuple[np.ndarray, np.ndarray]:
    """Discretize link bandwidths into ``n_classes`` classes (paper's knob).

    Each positive edge is replaced by the *floor* of its class (conservative:
    the algorithm never assumes more bandwidth than the link has).  With
    ``n_classes=None`` the graph is returned unquantized (infinite classes).
    Returns (quantized bw matrix, ascending class floor values).
    """
    bw = np.asarray(bw, dtype=float)
    pos = bw[bw > 0]
    if n_classes is None or pos.size == 0:
        vals = np.unique(pos) if pos.size else np.array([])
        return bw.copy(), vals
    n_classes = max(1, int(n_classes))
    lo, hi = pos.min(), pos.max()
    if scheme == "quantile":
        qs = np.quantile(pos, np.linspace(0.0, 1.0, n_classes + 1))
    elif scheme == "geometric":
        qs = np.geomspace(lo, hi, n_classes + 1) if lo > 0 else np.linspace(lo, hi, n_classes + 1)
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    floors = qs[:-1]
    # map each edge to the floor of its bucket
    idx = np.clip(np.searchsorted(qs, bw, side="right") - 1, 0, n_classes - 1)
    out = np.where(bw > 0, floors[idx], 0.0)
    return out, np.unique(floors)


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacementResult:
    feasible: bool
    path: tuple[int, ...]
    bottleneck_latency: float  # on the TRUE (unquantized) bandwidths
    algorithm: str
    trials_used: int = 0

    @property
    def throughput(self) -> float:
        if not self.feasible:
            return 0.0
        return float("inf") if self.bottleneck_latency == 0 else 1.0 / self.bottleneck_latency


def _true_bottleneck(
    boundaries: Sequence[float],
    path: Sequence[int],
    comm: CommGraph,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
) -> float:
    lat = 0.0
    for i, w in enumerate(boundaries):
        b = comm.bw[path[i], path[i + 1]]
        lat = max(lat, np.inf if b <= 0 else w / b)
    if dispatcher is not None:
        if in_bytes > 0:
            b = comm.bw[dispatcher, path[0]]
            lat = max(lat, np.inf if b <= 0 else in_bytes / b)
        if out_bytes > 0:
            b = comm.bw[path[-1], dispatcher]
            lat = max(lat, np.inf if b <= 0 else out_bytes / b)
    return lat


def _infeasible(algo: str) -> PlacementResult:
    return PlacementResult(False, (), float("inf"), algo)


# ---------------------------------------------------------------------------
# Exact subset DP (minimax) -- oracle + small-n fast path
# ---------------------------------------------------------------------------

def _exact_minimax_path(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    bwq: np.ndarray,
    cap: np.ndarray,
) -> tuple[float, list[int]] | None:
    """Subset DP: dp[S][v] = min bottleneck placing first |S| parts, end v.

    Vectorized per popcount level: O(2^n * n^2) flops but only O(n*k) python
    iterations, so the Fig.3 simulation sweep stays fast.  Exact on the given
    (possibly quantized) bandwidth matrix.
    """
    n = bwq.shape[0]
    k = len(part_bytes)
    if k > n:
        return None
    if k == 1:
        idx = np.flatnonzero(cap >= part_bytes[0])
        return (0.0, [int(idx[0])]) if idx.size else None
    INF = np.inf
    nstates = 1 << n
    dp = np.full((nstates, n), INF)
    # latency matrices per boundary position: lat[pos][v, u] = w/bw(v,u)
    with np.errstate(divide="ignore"):
        lat = [np.where(bwq > 0, w / np.maximum(bwq, 1e-300), INF) for w in boundaries]
        for L in lat:
            np.fill_diagonal(L, INF)
    ok0 = np.flatnonzero(cap >= part_bytes[0])
    if ok0.size == 0:
        return None
    dp[1 << ok0, ok0] = 0.0
    popcount = np.array([bin(s).count("1") for s in range(nstates)], dtype=np.int32)
    subsets_by_pc = [np.flatnonzero(popcount == p) for p in range(n + 1)]
    for p in range(1, k):
        Ss = subsets_by_pc[p]
        block = dp[Ss]  # (m, n)
        finite_rows = np.isfinite(block).any(axis=1)
        if not finite_rows.any():
            return None  # dead end: no placement of first p partitions
        Ss, block = Ss[finite_rows], block[finite_rows]
        pos = p - 1
        # cand[m, u] = min over v of max(block[m, v], lat[pos][v, u])
        cand = np.min(np.maximum(block[:, :, None], lat[pos][None, :, :]), axis=1)
        okc = cap >= part_bytes[p]
        for u in range(n):
            if not okc[u]:
                continue
            bit = 1 << u
            mask = (Ss & bit) == 0
            if not mask.any():
                continue
            np.minimum.at(dp, (Ss[mask] | bit, u), cand[mask, u])
    # best over |S| == k
    Sk = subsets_by_pc[k]
    vals = dp[Sk]
    flat = int(np.argmin(vals))
    best_state = int(Sk[flat // n])
    best_v = flat % n
    best_val = float(vals[flat // n, flat % n])
    if not np.isfinite(best_val):
        return None
    # reconstruct by walking equalities backwards (maxes are exact copies)
    path = [best_v]
    S, v, val = best_state, best_v, best_val
    for p in range(k - 1, 0, -1):
        Sp = S & ~(1 << v)
        found = False
        for u in range(n):
            if not (Sp >> u) & 1:
                continue
            step = max(dp[Sp, u], lat[p - 1][u, v])
            if step == val or (np.isfinite(step) and step <= val + 1e-18):
                S, v, val = Sp, u, float(dp[Sp, u])
                path.append(u)
                found = True
                break
        if not found:  # pragma: no cover - defensive
            return None
    path.reverse()
    return best_val, path


# ---------------------------------------------------------------------------
# Color-coding k-path feasibility (large n)
# ---------------------------------------------------------------------------

def _color_coding_feasible(
    feas: list[np.ndarray],  # per-position boolean edge feasibility (n, n)
    cap_ok: list[np.ndarray],  # per-position boolean node feasibility (n,)
    k: int,
    trials: int,
    rng: np.random.Generator,
) -> list[int] | None:
    """Alon-Yuster-Zwick color coding: random k-colorings + color-subset DP.

    Returns a feasible path (list of k node ids) or None.  Monte-Carlo: may
    miss a feasible path with probability <= (1 - k!/k^k)^trials.
    """
    if k == 1:
        idx = np.flatnonzero(cap_ok[0])
        return [int(idx[0])] if idx.size else None
    n = feas[0].shape[0]
    nstates = 1 << k
    popcount = np.array([bin(s).count("1") for s in range(nstates)], dtype=np.int32)
    order = np.argsort(popcount, kind="stable")
    for _ in range(trials):
        colors = rng.integers(0, k, size=n)
        color_bit = (1 << colors).astype(np.int64)
        dp = np.zeros((nstates, n), dtype=bool)
        parent = np.full((nstates, n), -1, dtype=np.int32)
        for v in range(n):
            if cap_ok[0][v]:
                dp[color_bit[v], v] = True
        found: tuple[int, int] | None = None
        for S in order:
            pc = popcount[S]
            if pc == 0 or pc >= k:
                continue
            row = dp[S]
            if not row.any():
                continue
            pos = pc - 1
            # reach[u] = any_v row[v] & feas[pos][v, u]
            reach = row @ feas[pos]  # bool matmul
            newmask = reach & cap_ok[pc] & ((color_bit & S) == 0)
            if not newmask.any():
                continue
            vs = np.flatnonzero(row)
            for u in np.flatnonzero(newmask):
                S2 = S | int(color_bit[u])
                if not dp[S2, u]:
                    dp[S2, u] = True
                    # any predecessor works; pick the first feasible
                    pred = vs[feas[pos][vs, u]][0]
                    parent[S2, u] = pred
                    if popcount[S2] == k:
                        found = (S2, u)
            if found:
                break
        if found:
            S, v = found
            path = [v]
            while parent[S, v] >= 0:
                u = int(parent[S, v])
                S &= ~int(1 << int(np.log2(int(color_bit[v]))))
                v = u
                path.append(v)
            path.reverse()
            return [int(x) for x in path]
    return None


# ---------------------------------------------------------------------------
# Public placement algorithms
# ---------------------------------------------------------------------------

@register_strategy(
    "placer", "color_coding", default=True,
    description="paper's placer: bandwidth-class quantization + min-bottleneck "
                "k-path (exact subset DP small n, color coding large n)",
)
def place_color_coding(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
    n_classes: int | None = 4,
    trials: int = 60,
    seed: int = 0,
    exact_limit: int = EXACT_NODE_LIMIT,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
) -> PlacementResult:
    """SEIFER placement: bandwidth-class quantization + min-bottleneck k-path.

    Small clusters (n <= exact_limit) use the exact subset DP on the
    quantized graph; larger clusters binary-search the candidate bottleneck
    latencies with color-coding feasibility checks.  The reported bottleneck
    latency is always evaluated on the TRUE bandwidths of the found path.
    """
    algo = f"color_coding(c={n_classes})"
    k = len(part_bytes)
    if k == 0 or k > comm.n:
        return _infeasible(algo)
    bwq, class_vals = quantize_bandwidths(comm.bw, n_classes)
    cap = comm.node_capacity

    if comm.n <= exact_limit:
        res = _exact_minimax_path(boundaries, part_bytes, bwq, cap)
        if res is None:
            return _infeasible(algo)
        _, path = res
        lat = _true_bottleneck(boundaries, path, comm, in_bytes, out_bytes, dispatcher)
        return PlacementResult(True, tuple(path), float(lat), algo)

    # ---- large n: binary search over candidate latencies ----
    rng = np.random.default_rng(seed)
    cands = sorted(
        {w / c for w in boundaries for c in class_vals if c > 0 and w > 0} | {0.0}
    )
    if not cands:
        cands = [0.0]
    cap_ok = [cap >= pb for pb in part_bytes]
    lo, hi = 0, len(cands) - 1
    best_path: list[int] | None = None
    trials_used = 0
    while lo <= hi:
        mid = (lo + hi) // 2
        L = cands[mid]
        feas = [
            (bwq > 0) & (bwq * max(L, 1e-300) >= w) if w > 0 else (bwq > 0)
            for w in boundaries
        ]
        path = _color_coding_feasible(feas, cap_ok, k, trials, rng)
        trials_used += trials
        if path is not None:
            best_path = path
            hi = mid - 1
        else:
            lo = mid + 1
    if best_path is None:
        return _infeasible(algo)
    lat = _true_bottleneck(boundaries, best_path, comm, in_bytes, out_bytes, dispatcher)
    return PlacementResult(True, tuple(best_path), float(lat), algo, trials_used)


@register_strategy(
    "placer", "greedy",
    description="left-to-right greedy: always take the fastest feasible link",
)
def place_greedy(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
) -> PlacementResult:
    """Left-to-right greedy: from every start node, repeatedly take the
    highest-bandwidth feasible edge.  Cheap baseline (paper's 'edge
    matching' in its simplest form)."""
    algo = "greedy"
    k = len(part_bytes)
    n = comm.n
    if k == 0 or k > n:
        return _infeasible(algo)
    best: tuple[float, list[int]] | None = None
    for start in range(n):
        if comm.node_capacity[start] < part_bytes[0]:
            continue
        path = [start]
        used = {start}
        ok = True
        for pos in range(k - 1):
            v = path[-1]
            cand_bw = np.array(
                [
                    comm.bw[v, u]
                    if u not in used and comm.node_capacity[u] >= part_bytes[pos + 1]
                    else -1.0
                    for u in range(n)
                ]
            )
            u = int(np.argmax(cand_bw))
            if cand_bw[u] <= 0:
                ok = False
                break
            path.append(u)
            used.add(u)
        if not ok:
            continue
        lat = _true_bottleneck(boundaries, path, comm, in_bytes, out_bytes, dispatcher)
        if best is None or lat < best[0]:
            best = (lat, path)
    if best is None:
        return _infeasible(algo)
    return PlacementResult(True, tuple(best[1]), float(best[0]), algo)


@register_strategy(
    "placer", "random",
    description="random feasible path -- the no-algorithm baseline",
)
def place_random(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
    seed: int = 0,
    attempts: int = 20,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
) -> PlacementResult:
    """Random feasible path -- the no-algorithm baseline."""
    algo = "random"
    rng = np.random.default_rng(seed)
    k = len(part_bytes)
    n = comm.n
    if k == 0 or k > n:
        return _infeasible(algo)
    for _ in range(attempts):
        perm = rng.permutation(n)[:k]
        if any(comm.node_capacity[perm[j]] < part_bytes[j] for j in range(k)):
            continue
        if any(comm.bw[perm[i], perm[i + 1]] <= 0 for i in range(k - 1)):
            continue
        lat = _true_bottleneck(boundaries, list(perm), comm, in_bytes, out_bytes, dispatcher)
        return PlacementResult(True, tuple(int(x) for x in perm), float(lat), algo)
    return _infeasible(algo)


@register_strategy(
    "placer", "optimal",
    description="exact optimum on TRUE bandwidths (subset DP, n <= 16)",
)
def place_optimal(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
    in_bytes: float = 0.0,
    out_bytes: float = 0.0,
    dispatcher: int | None = None,
) -> PlacementResult:
    """Exact optimum on the TRUE bandwidths (subset DP).  n <= 14 only.

    Used for the approximation-ratio benchmark (paper Sec. 4, item 2).
    """
    algo = "optimal"
    if comm.n > EXACT_NODE_LIMIT:
        raise ValueError(f"place_optimal limited to n <= {EXACT_NODE_LIMIT}")
    k = len(part_bytes)
    if k == 0 or k > comm.n:
        return _infeasible(algo)
    res = _exact_minimax_path(boundaries, part_bytes, comm.bw, comm.node_capacity)
    if res is None:
        return _infeasible(algo)
    _, path = res
    lat = _true_bottleneck(boundaries, path, comm, in_bytes, out_bytes, dispatcher)
    return PlacementResult(True, tuple(path), float(lat), algo)


def place_brute_force(
    boundaries: Sequence[float],
    part_bytes: Sequence[float],
    comm: CommGraph,
) -> PlacementResult:
    """Permutation brute force (n <= 8) -- test oracle for place_optimal."""
    algo = "brute_force"
    n, k = comm.n, len(part_bytes)
    if n > 8:
        raise ValueError("brute force limited to n <= 8")
    if k == 0 or k > n:
        return _infeasible(algo)
    best: tuple[float, tuple[int, ...]] | None = None
    for perm in itertools.permutations(range(n), k):
        if any(comm.node_capacity[perm[j]] < part_bytes[j] for j in range(k)):
            continue
        lat = _true_bottleneck(boundaries, perm, comm)
        if np.isfinite(lat) and (best is None or lat < best[0]):
            best = (lat, perm)
    if best is None:
        return _infeasible(algo)
    return PlacementResult(True, best[1], float(best[0]), algo)
