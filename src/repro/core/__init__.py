"""SEIFER core: DNN partitioning + placement for max-throughput inference.

Exports resolve lazily (PEP 562): ``from repro.core import CommGraph`` works
as before, but importing a leaf like ``repro.core.registry`` no longer drags
in the whole algorithm stack.  That laziness is load-bearing -- the shared
registry helper lives here and is imported by ``repro.api.registry``, which
the algorithm modules import back to self-register; an eager ``__init__``
would close that loop into a circular import.
"""

_SUBMODULE_EXPORTS = {
    "bottleneck": ("PipelineMetrics", "evaluate_pipeline", "link_latencies"),
    "graph": (
        "Layer",
        "LayerGraph",
        "Partition",
        "boundary_bytes",
        "chain",
        "make_partitions",
    ),
    "joint": ("JointResult", "joint", "sequential"),
    "partitioner": (
        "PartitionResult",
        "partition_exact_k",
        "partition_exhaustive",
        "partition_fewest_parts",
        "partition_min_bottleneck",
        "partition_min_sum",
        "partition_paper_greedy",
    ),
    "placement": (
        "CommGraph",
        "PlacementResult",
        "place_brute_force",
        "place_color_coding",
        "place_greedy",
        "place_hierarchical",
        "place_optimal",
        "place_random",
        "quantize_bandwidths",
    ),
}

_NAME_TO_MODULE = {
    name: mod for mod, names in _SUBMODULE_EXPORTS.items() for name in names
}

__all__ = sorted(_NAME_TO_MODULE)


def __getattr__(name):
    mod = _NAME_TO_MODULE.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(f"{__name__}.{mod}"), name)
    globals()[name] = value  # cache: subsequent lookups skip __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
