"""SEIFER core: DNN partitioning + placement for max-throughput inference."""

from repro.core.bottleneck import PipelineMetrics, evaluate_pipeline, link_latencies
from repro.core.graph import (
    Layer,
    LayerGraph,
    Partition,
    boundary_bytes,
    chain,
    make_partitions,
)
from repro.core.joint import JointResult, joint, sequential
from repro.core.partitioner import (
    PartitionResult,
    partition_exact_k,
    partition_exhaustive,
    partition_fewest_parts,
    partition_min_bottleneck,
    partition_min_sum,
    partition_paper_greedy,
)
from repro.core.placement import (
    CommGraph,
    PlacementResult,
    place_brute_force,
    place_color_coding,
    place_greedy,
    place_optimal,
    place_random,
    quantize_bandwidths,
)

__all__ = [
    "Layer",
    "LayerGraph",
    "Partition",
    "boundary_bytes",
    "chain",
    "make_partitions",
    "PartitionResult",
    "partition_exact_k",
    "partition_exhaustive",
    "partition_fewest_parts",
    "partition_min_bottleneck",
    "partition_min_sum",
    "partition_paper_greedy",
    "CommGraph",
    "PlacementResult",
    "place_brute_force",
    "place_color_coding",
    "place_greedy",
    "place_optimal",
    "place_random",
    "quantize_bandwidths",
    "PipelineMetrics",
    "evaluate_pipeline",
    "link_latencies",
    "JointResult",
    "joint",
    "sequential",
]
