"""DNN partitioning: contiguous layer ranges under a per-node memory cap.

The paper (SEIFER Sec. 2.2-1b): "Find the model partitions such that the
least amount of data is transferred between model layers, and such that each
model partition will fit within the compute node's memory."

Because the end-to-end objective is *bottleneck* latency (max over links),
the primary partitioner here minimizes the **maximum** cut-edge weight
(min-max cut).  We also provide:

  * ``partition_paper_greedy``   -- the paper's capacity-filling greedy that
    backtracks to the cheapest recent edge (SEIFER's published description is
    a sketch; this is the natural reading and serves as the paper baseline).
  * ``partition_min_sum``        -- DP minimizing *total* transferred bytes
    (the natural alternative objective; used in the ablation benchmark).
  * ``partition_min_bottleneck`` -- optimal min-max cut via binary search
    over edge weights + greedy feasibility (exact, O(E log E * n)).
  * ``partition_exact_k``        -- min-max cut with exactly k parts (DP).
  * ``partition_exhaustive``     -- brute-force oracle for tests.

All functions return a ``PartitionResult``; infeasible inputs (a single
layer exceeding capacity, or more parts required than allowed) yield
``feasible=False`` rather than raising, so the placement layer / simulator
can score infeasible configs.

Every algorithm self-registers in the strategy registry
(``repro.api.registry``) under the names the declarative API uses:
``min_bottleneck`` (default), ``paper_greedy``, ``min_sum``, ``exact_k``
(minimal-part-count variant), ``uniform`` (equal-layer-count baseline),
``exhaustive``.  The shared registered signature is
``fn(graph, capacity, max_parts=None) -> PartitionResult``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

from repro.api.registry import register_strategy
from repro.core.graph import LayerGraph, Partition, boundary_bytes, make_partitions


@dataclasses.dataclass(frozen=True)
class PartitionResult:
    feasible: bool
    cuts: tuple[int, ...]  # edge indices that were cut
    partitions: tuple[Partition, ...]
    max_cut_bytes: int  # max activation bytes over cut edges (0 if no cut)
    total_cut_bytes: int
    algorithm: str

    @property
    def n_parts(self) -> int:
        return len(self.partitions)

    @property
    def boundaries(self) -> tuple[int, ...]:
        return boundary_bytes(self.partitions)


def _result(graph: LayerGraph, cuts: Sequence[int], algo: str) -> PartitionResult:
    parts = make_partitions(graph, cuts)
    bounds = boundary_bytes(parts)
    return PartitionResult(
        feasible=True,
        cuts=tuple(sorted(cuts)),
        partitions=parts,
        max_cut_bytes=max(bounds, default=0),
        total_cut_bytes=sum(bounds),
        algorithm=algo,
    )


def _infeasible(algo: str) -> PartitionResult:
    return PartitionResult(
        feasible=False,
        cuts=(),
        partitions=(),
        max_cut_bytes=0,
        total_cut_bytes=0,
        algorithm=algo,
    )


def _fits(graph: LayerGraph, capacity: int) -> bool:
    """Every single layer must fit on a node, else no partition exists."""
    return all(l.param_bytes <= capacity for l in graph.layers)


# ---------------------------------------------------------------------------
# Paper greedy
# ---------------------------------------------------------------------------

@register_strategy(
    "partitioner", "paper_greedy",
    description="paper's capacity-filling greedy, cheapest-recent-edge backtracking",
)
def partition_paper_greedy(
    graph: LayerGraph, capacity: int, max_parts: int | None = None
) -> PartitionResult:
    """Capacity-filling greedy with cheapest-recent-edge backtracking.

    Walk the chain accumulating layers.  When the running segment would
    exceed ``capacity``, cut at the minimum-weight edge *inside* the current
    segment (not necessarily the last edge), then restart accumulation after
    the cut.  This realizes "least data transferred subject to fitting".
    A ``max_parts`` budget the greedy overruns yields ``feasible=False``.
    """
    algo = "paper_greedy"
    if not _fits(graph, capacity):
        return _infeasible(algo)
    n = len(graph)
    cuts: list[int] = []
    seg_start = 0
    acc = 0
    i = 0
    while i < n:
        w = graph.layers[i].param_bytes
        if acc + w <= capacity:
            acc += w
            i += 1
            continue
        # must cut inside [seg_start, i); pick the cheapest edge
        best_edge = min(
            range(seg_start, i), key=lambda e: (graph.edge_bytes(e), e)
        )
        cuts.append(best_edge)
        seg_start = best_edge + 1
        acc = graph.segment_param_bytes(seg_start, i)
        # re-check: remaining prefix may still exceed capacity; loop continues
        if acc > capacity:
            # the cheapest edge was too early; fall back to cutting just
            # before i (always reduces the segment)
            cuts[-1] = i - 1
            seg_start = i
            acc = 0
    if max_parts is not None and len(cuts) + 1 > max_parts:
        return _infeasible(algo)
    return _result(graph, cuts, algo)


# ---------------------------------------------------------------------------
# Optimal min-max cut
# ---------------------------------------------------------------------------

def _feasible_with_threshold(
    graph: LayerGraph, capacity: int, thresh: int, max_parts: int | None
) -> list[int] | None:
    """Greedy feasibility: partition using only edges with weight <= thresh.

    Cut as *late* as possible (minimizes part count).  Returns cuts or None.
    """
    n = len(graph)
    cuts: list[int] = []
    seg_start = 0
    acc = 0
    last_ok_edge = -1  # latest allowed edge index inside the current segment
    for i in range(n):
        w = graph.layers[i].param_bytes
        if acc + w > capacity:
            if last_ok_edge < seg_start:
                return None  # no allowed cut inside the segment
            cuts.append(last_ok_edge)
            seg_start = last_ok_edge + 1
            acc = graph.segment_param_bytes(seg_start, i)
            last_ok_edge = seg_start - 1
            if acc + w > capacity:
                return None  # even after the cut, prefix too big (rare)
        acc += w
        if i < n - 1 and graph.edge_bytes(i) <= thresh:
            last_ok_edge = i
    if max_parts is not None and len(cuts) + 1 > max_parts:
        return None
    return cuts


@register_strategy(
    "partitioner", "min_bottleneck", default=True,
    description="exact min of max cut-edge bytes (binary search + late-cut greedy)",
)
def partition_min_bottleneck(
    graph: LayerGraph, capacity: int, max_parts: int | None = None
) -> PartitionResult:
    """Exact minimum of max-cut-edge weight, subject to capacity/part count.

    Binary search over the sorted distinct edge weights; each candidate is
    checked with the late-cut greedy (optimal for interval feasibility).
    If the whole model fits on one node, returns the trivial partition.
    """
    algo = "min_bottleneck"
    if not _fits(graph, capacity):
        return _infeasible(algo)
    if graph.total_param_bytes <= capacity:
        return _result(graph, [], algo)
    weights = sorted(set(graph.edges))
    lo, hi = 0, len(weights) - 1
    best: list[int] | None = None
    while lo <= hi:
        mid = (lo + hi) // 2
        cuts = _feasible_with_threshold(graph, capacity, weights[mid], max_parts)
        if cuts is not None:
            best = cuts
            hi = mid - 1
        else:
            lo = mid + 1
    if best is None:
        return _infeasible(algo)
    return _result(graph, best, algo)


# ---------------------------------------------------------------------------
# Min total transfer (DP)
# ---------------------------------------------------------------------------

@register_strategy(
    "partitioner", "min_sum",
    description="DP minimizing total transferred bytes over all cuts",
)
def partition_min_sum(
    graph: LayerGraph, capacity: int, max_parts: int | None = None
) -> PartitionResult:
    """DP minimizing the total bytes over all cuts. O(n^2 * k)."""
    algo = "min_sum"
    if not _fits(graph, capacity):
        return _infeasible(algo)
    n = len(graph)
    kmax = max_parts if max_parts is not None else n
    prefix = graph.prefix_param_bytes()
    INF = float("inf")
    # dp[j][i] = min total cut bytes splitting layers[:i] into j parts
    dp = [[INF] * (n + 1) for _ in range(kmax + 1)]
    par: dict[tuple[int, int], int] = {}
    dp[0][0] = 0.0
    for j in range(1, kmax + 1):
        for i in range(1, n + 1):
            for s in range(i):  # previous boundary: layers[s:i] is part j
                if prefix[i] - prefix[s] > capacity:
                    continue
                cost = dp[j - 1][s] + (graph.edge_bytes(s - 1) if s > 0 else 0)
                if cost < dp[j][i]:
                    dp[j][i] = cost
                    par[(j, i)] = s
    best_j = min(
        (j for j in range(1, kmax + 1) if dp[j][n] < INF),
        key=lambda j: dp[j][n],
        default=None,
    )
    if best_j is None:
        return _infeasible(algo)
    cuts: list[int] = []
    i, j = n, best_j
    while j > 0:
        s = par[(j, i)]
        if s > 0:
            cuts.append(s - 1)
        i, j = s, j - 1
    return _result(graph, cuts, algo)


# ---------------------------------------------------------------------------
# Exactly-k min-max cut (DP)
# ---------------------------------------------------------------------------

def partition_exact_k(graph: LayerGraph, capacity: int, k: int) -> PartitionResult:
    """Minimize max cut weight with *exactly* k parts. O(n^2 k)."""
    algo = "exact_k"
    if k < 1 or not _fits(graph, capacity):
        return _infeasible(algo)
    n = len(graph)
    if k > n:
        return _infeasible(algo)
    prefix = graph.prefix_param_bytes()
    INF = float("inf")
    dp = [[INF] * (n + 1) for _ in range(k + 1)]
    par: dict[tuple[int, int], int] = {}
    dp[0][0] = 0.0
    for j in range(1, k + 1):
        for i in range(1, n + 1):
            for s in range(i):
                if prefix[i] - prefix[s] > capacity:
                    continue
                edge = graph.edge_bytes(s - 1) if s > 0 else 0
                cost = max(dp[j - 1][s], edge)
                if cost < dp[j][i]:
                    dp[j][i] = cost
                    par[(j, i)] = s
    if dp[k][n] == INF:
        return _infeasible(algo)
    cuts: list[int] = []
    i, j = n, k
    while j > 0:
        s = par[(j, i)]
        if s > 0:
            cuts.append(s - 1)
        i, j = s, j - 1
    return _result(graph, cuts, algo)


@register_strategy(
    "partitioner", "exact_k",
    description="min-max cut at the minimal feasible part count (fewest pods)",
)
def partition_fewest_parts(
    graph: LayerGraph, capacity: int, max_parts: int | None = None
) -> PartitionResult:
    """Min-max cut with the *fewest* parts that fit capacity.

    ``min_bottleneck`` happily spends extra parts to shave the max cut; this
    strategy first finds the minimal feasible part count (late-cut greedy
    with every edge allowed), then runs the exact-k DP at that count -- the
    cheapest deployment in pods, optimal among same-size partitions.
    """
    algo = "exact_k"
    if not _fits(graph, capacity):
        return _infeasible(algo)
    max_edge = max(graph.edges, default=0)
    cuts = _feasible_with_threshold(graph, capacity, max_edge, max_parts)
    if cuts is None:
        return _infeasible(algo)
    return partition_exact_k(graph, capacity, len(cuts) + 1)


# ---------------------------------------------------------------------------
# Uniform split (algorithm-free baseline)
# ---------------------------------------------------------------------------

@register_strategy(
    "partitioner", "uniform",
    description="equal-layer-count split at the fewest feasible parts (baseline)",
)
def partition_uniform(
    graph: LayerGraph, capacity: int, max_parts: int | None = None
) -> PartitionResult:
    """Split into k near-equal-layer-count parts, smallest feasible k.

    The no-algorithm baseline: cut positions ignore edge weights entirely
    (cut after layer ``round(i * n / k)`` for i = 1..k-1), so its min-max cut
    is whatever those arbitrary edges happen to weigh.  ``exact_k`` at the
    same k is optimal among k-part partitions, which the property suite
    exploits as an ordering oracle.
    """
    algo = "uniform"
    if not _fits(graph, capacity):
        return _infeasible(algo)
    n = len(graph)
    kmax = min(max_parts, n) if max_parts is not None else n
    for k in range(1, kmax + 1):
        # strictly increasing for k <= n (consecutive targets differ by
        # n/k >= 1), so every part is non-empty
        bounds = [round(i * n / k) for i in range(k + 1)]
        if all(
            graph.segment_param_bytes(bounds[i], bounds[i + 1]) <= capacity
            for i in range(k)
        ):
            cuts = [b - 1 for b in bounds[1:-1]]
            return _result(graph, cuts, algo)
    return _infeasible(algo)


# ---------------------------------------------------------------------------
# Exhaustive oracle (tests only)
# ---------------------------------------------------------------------------

@register_strategy(
    "partitioner", "exhaustive",
    description="brute-force oracle over all cut subsets (<= 18 layers)",
)
def partition_exhaustive(
    graph: LayerGraph, capacity: int, max_parts: int | None = None
) -> PartitionResult:
    """Brute force over all cut subsets; minimizes (max_cut, total_cut, k)."""
    algo = "exhaustive"
    n = len(graph)
    if n > 18:
        raise ValueError("exhaustive oracle limited to 18 layers")
    if not _fits(graph, capacity):
        return _infeasible(algo)
    best: PartitionResult | None = None
    for r in range(n):
        if max_parts is not None and r + 1 > max_parts:
            break
        for cuts in itertools.combinations(range(n - 1), r):
            parts = make_partitions(graph, cuts)
            if any(p.param_bytes > capacity for p in parts):
                continue
            cand = _result(graph, cuts, algo)
            key = (cand.max_cut_bytes, cand.total_cut_bytes, cand.n_parts)
            if best is None or key < (
                best.max_cut_bytes,
                best.total_cut_bytes,
                best.n_parts,
            ):
                best = cand
    return best if best is not None else _infeasible(algo)
