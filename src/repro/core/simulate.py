"""Paper Fig. 3 simulation: spatially-random edge clusters, wireless links.

"we simulated a set of randomly placed edge devices with a wireless network
whose link bandwidths are modeled realistically as a function of inter-node
distances" -- nodes are placed uniformly at random in a square arena; link
bandwidth follows a log-distance path-loss model mapped through Shannon
capacity (a standard 802.11-style model).  Each (model, capacity, n_nodes,
n_classes) cell is run ``trials`` times (paper: 50) and averaged.

Node 0 is the *dispatcher* (leader): it feeds model input to the first
partition and receives the final output; it never hosts a partition
(capacity -1), matching the paper's dispatcher/compute-node split.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

from repro.core.graph import LayerGraph
from repro.core.partitioner import partition_min_bottleneck
from repro.core.placement import CommGraph, place_color_coding

# ---------------------------------------------------------------------------
# Wireless link model
# ---------------------------------------------------------------------------

TX_POWER_DBM = 20.0  # typical AP/client
PATHLOSS_1M_DB = 40.0  # free-space at 2.4/5 GHz, 1 m
PATHLOSS_EXP = 3.0  # indoor/obstructed
NOISE_FLOOR_DBM = -90.0
CHANNEL_HZ = 20e6
MAX_LINK_BPS = 600e6  # PHY cap
MIN_SNR_DB = 0.0  # below this the link is unusable


def wireless_bandwidth_bps(dist_m: np.ndarray) -> np.ndarray:
    """Log-distance path loss -> Shannon capacity, in bits/s."""
    d = np.maximum(np.asarray(dist_m, dtype=float), 1.0)
    pl = PATHLOSS_1M_DB + 10.0 * PATHLOSS_EXP * np.log10(d)
    snr_db = TX_POWER_DBM - pl - NOISE_FLOOR_DBM
    snr = 10.0 ** (snr_db / 10.0)
    cap = CHANNEL_HZ * np.log2(1.0 + snr)
    cap = np.minimum(cap, MAX_LINK_BPS)
    return np.where(snr_db >= MIN_SNR_DB, cap, 0.0)


def cluster_from_positions(
    pos: np.ndarray, capacity_bytes: float, dispatcher_idx: int | None = 0
) -> CommGraph:
    """Wireless CommGraph from (n, 2) node positions.

    ``dispatcher_idx`` (if set) gets capacity -1: it hosts no partition,
    matching the paper's dispatcher/compute-node split.
    """
    pos = np.asarray(pos, dtype=float)
    d = np.linalg.norm(pos[:, None, :] - pos[None, :, :], axis=-1)
    bw_bps = wireless_bandwidth_bps(d)
    np.fill_diagonal(bw_bps, 0.0)
    bw_bytes = bw_bps / 8.0
    cap = np.full(len(pos), float(capacity_bytes))
    if dispatcher_idx is not None:
        cap[dispatcher_idx] = -1.0
    return CommGraph(bw=bw_bytes, node_capacity=cap)


def random_cluster(
    n_nodes: int,
    capacity_bytes: float,
    arena_m: float = 100.0,
    seed: int = 0,
    *,
    with_positions: bool = False,
) -> CommGraph | tuple[CommGraph, np.ndarray]:
    """n_nodes compute nodes + dispatcher (index 0), random positions.

    With ``with_positions=True`` also returns the (n+1, 2) position array so
    the cluster can later be grown with ``expand_cluster`` (node-join churn).
    """
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.0, arena_m, size=(n_nodes + 1, 2))
    comm = cluster_from_positions(pos, capacity_bytes)
    return (comm, pos) if with_positions else comm


def expand_cluster(
    positions: np.ndarray,
    capacity_bytes: float,
    arena_m: float = 100.0,
    seed: int = 0,
) -> tuple[CommGraph, np.ndarray]:
    """Add one node at a random position; bandwidths re-derived from geometry.

    Existing pairwise links are unchanged (same positions -> same distances),
    so the result is valid for ``EdgeCluster.add_node``.  Returns the grown
    CommGraph and the grown position array.
    """
    rng = np.random.default_rng(seed)
    new_pos = np.vstack([positions, rng.uniform(0.0, arena_m, size=(1, 2))])
    return cluster_from_positions(new_pos, capacity_bytes), new_pos


# ---------------------------------------------------------------------------
# Single trial & sweep
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrialResult:
    model: str
    capacity: float
    n_nodes: int
    n_classes: int
    seed: int
    feasible: bool
    n_parts: int
    bottleneck_latency: float  # seconds; inf if infeasible
    throughput: float  # inferences/s


def run_trial(
    graph: LayerGraph,
    capacity_bytes: float,
    n_nodes: int,
    n_classes: int | None,
    seed: int,
    arena_m: float = 100.0,
    placer: Callable = place_color_coding,
    include_dispatcher: bool = True,
) -> TrialResult:
    comm = random_cluster(n_nodes, capacity_bytes, arena_m, seed)
    part = partition_min_bottleneck(graph, int(capacity_bytes), max_parts=n_nodes)
    if not part.feasible:
        return TrialResult(
            graph.name, capacity_bytes, n_nodes, n_classes or 0, seed,
            False, 0, float("inf"), 0.0,
        )
    kwargs = dict(
        in_bytes=graph.in_bytes if include_dispatcher else 0.0,
        out_bytes=graph.layers[-1].out_bytes if include_dispatcher else 0.0,
        dispatcher=0 if include_dispatcher else None,
    )
    if placer is place_color_coding:
        kwargs["n_classes"] = n_classes
        kwargs["seed"] = seed
    place = placer(
        part.boundaries, [p.param_bytes for p in part.partitions], comm, **kwargs
    )
    return TrialResult(
        graph.name,
        capacity_bytes,
        n_nodes,
        n_classes or 0,
        seed,
        place.feasible,
        part.n_parts,
        place.bottleneck_latency,
        place.throughput if place.feasible else 0.0,
    )


def sweep(
    models: Mapping[str, Callable[[], LayerGraph]],
    capacities: Sequence[float],
    node_counts: Sequence[int],
    class_counts: Sequence[int | None],
    trials: int = 50,
    arena_m: float = 100.0,
    placer: Callable = place_color_coding,
    base_seed: int = 0,
) -> list[TrialResult]:
    """Full Fig.3-style sweep.  Returns one TrialResult per trial."""
    results: list[TrialResult] = []
    graphs = {name: fn() for name, fn in models.items()}
    for (mname, graph), cap, n, c in itertools.product(
        graphs.items(), capacities, node_counts, class_counts
    ):
        for t in range(trials):
            seed = base_seed + 7919 * t + hash((mname, cap, n, c)) % 10007
            results.append(
                run_trial(graph, cap, n, c, seed, arena_m, placer=placer)
            )
    return results


def aggregate(results: Iterable[TrialResult]) -> dict[tuple, dict[str, float]]:
    """Mean bottleneck latency / throughput per (model, cap, nodes, classes).

    Infeasible trials are excluded from the latency mean but reported via
    ``feasible_frac`` (the paper averages over feasible runs).
    """
    cells: dict[tuple, list[TrialResult]] = {}
    for r in results:
        cells.setdefault((r.model, r.capacity, r.n_nodes, r.n_classes), []).append(r)
    out: dict[tuple, dict[str, float]] = {}
    for key, rs in sorted(cells.items()):
        feas = [r for r in rs if r.feasible and np.isfinite(r.bottleneck_latency)]
        out[key] = {
            "mean_bottleneck_s": float(np.mean([r.bottleneck_latency for r in feas]))
            if feas
            else float("inf"),
            "mean_throughput": float(np.mean([r.throughput for r in feas])) if feas else 0.0,
            "mean_parts": float(np.mean([r.n_parts for r in feas])) if feas else 0.0,
            "feasible_frac": len(feas) / len(rs),
            "n_trials": float(len(rs)),
        }
    return out
