"""SEIFER pipeline over (simulated) pods: GPipe + placement + int8 boundaries.

Must set the device-count flag BEFORE importing jax, so run as a script:

    PYTHONPATH=src python examples/pipeline_pods.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.graph import chain  # noqa: E402
from repro.runtime.pipeline import (  # noqa: E402
    make_gpipe,
    plan_pipeline,
    reorder_stage_params,
)

mesh = jax.make_mesh((4,), ("stage",))
D, LAYERS, N_MICRO = 64, 8, 8

ws = jax.random.normal(jax.random.PRNGKey(0), (LAYERS, D, D), jnp.float32) * 0.1
stage_ws = ws.reshape(4, 2, D, D)


def stage_fn(local_w, x):
    for i in range(2):
        x = jnp.tanh(x @ local_w[i])
    return x


# pods with heterogeneous DCN links: SEIFER places the chain on the fastest
graph = chain("mlp8", [(D * D * 4, 32 * D * 4)] * LAYERS)
pod_bw = np.array(
    [[0, 12e9, 2e9, 2e9], [12e9, 0, 6e9, 2e9],
     [2e9, 6e9, 0, 3e9], [2e9, 2e9, 3e9, 0]], float)
plan = plan_pipeline(graph, 4, stage_capacity=2 * D * D * 4, pod_bw=pod_bw)
print(f"SEIFER cuts: {plan.cuts}; stage->pod order: {plan.stage_order}; "
      f"est bottleneck {plan.est_bottleneck_s*1e6:.2f} us")

x = jax.random.normal(jax.random.PRNGKey(1), (N_MICRO, 32, D), jnp.float32)
ref = x
for i in range(LAYERS):
    ref = jnp.tanh(ref @ ws[i])

for compress in (False, True):
    pipe = make_gpipe(stage_fn, mesh, axis="stage", n_micro=N_MICRO,
                      compress=compress, quant_block=64,
                      stage_order=plan.stage_order)
    with mesh:
        y = pipe(reorder_stage_params(stage_ws, plan), x)
    err = float(jnp.max(jnp.abs(y - ref)))
    label = "int8-compressed boundaries" if compress else "bf16 boundaries"
    print(f"{label}: max |err| vs sequential = {err:.5f}")
print("pipeline example complete.")
