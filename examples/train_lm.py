"""Train a small LM end-to-end: synthetic data pipeline, AdamW, grad clip,
checkpoint/restore mid-run (fault-tolerance path exercised for real).

    PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models import lm
from repro.runtime import train as train_lib
from repro.runtime.checkpoint import Checkpointer


def data_stream(vocab: int, batch: int, seq: int, seed: int = 0):
    """Synthetic Zipf-ish token stream with induced bigram structure."""
    key = jax.random.PRNGKey(seed)
    bigram = jax.random.randint(jax.random.PRNGKey(7), (vocab,), 0, vocab)
    while True:
        key, k1 = jax.random.split(key)
        first = jax.random.categorical(
            k1, -jnp.log1p(jnp.arange(vocab, dtype=jnp.float32)), shape=(batch, 1)
        )
        rows = [first]
        for _ in range(seq - 1):
            rows.append(bigram[rows[-1]])  # deterministic bigram: learnable
        yield {"tokens": jnp.concatenate(rows, axis=1).astype(jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(ARCHS["llama3.2-1b"], layers=4, d_model=256, vocab=2048)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=args.seq)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"training {cfg.name} (reduced, {n_params/1e6:.1f}M params)")

    state = train_lib.init_state(cfg, params)
    opt = train_lib.OptConfig(lr=3e-3, warmup_steps=10)
    step_fn = jax.jit(train_lib.make_train_step(cfg, opt))
    ckpt = Checkpointer(tempfile.mkdtemp(prefix="ckpt-"))
    stream = data_stream(cfg.vocab_size, args.batch, args.seq)

    t0 = time.perf_counter()
    for i in range(args.steps):
        state, metrics = step_fn(state, next(stream))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if i == args.steps // 2:
            ckpt.save(i, state)  # mid-run checkpoint
            _, state = ckpt.restore(state)  # ...and prove restore works
            print(f"checkpoint saved+restored at step {i}")
    print(f"done in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
