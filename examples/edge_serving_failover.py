"""End-to-end SEIFER lifecycle through the control plane's event API.

bootstrap (elect -> probe -> partition/place -> deploy) -> serve a request
stream -> node failure mid-stream -> reconcile (re-place) -> model-version
update -> reconcile (in-place redeploy) -> node join -> reconcile (full
cluster restart), with every convergence step driven by typed events --
no manual ``Dispatcher.recover()`` calls.

    PYTHONPATH=src python examples/edge_serving_failover.py

Expected output (paths/latencies vary slightly with placement seeds):

    bootstrap: 4 partitions on nodes [4, 3, 5, 2], bottleneck 0.159 ms
    served 8 requests, clock 0.107 ms
    NodeFailed(3) -> [('replace', 're-placed 1 pod(s) off node 3')]
    recovered: path [2, 5, 6, 1], outputs identical: True
    VersionBumped(1) -> [('redeploy', 'in-place redeploy at v1')]
    generation still 0 (no cluster restart on a version bump)
    NodeJoined(new node 9) -> [('restart', 'full restart (gen 1) after node 9 joined')]
    lifecycle complete: v1, generation 1, 0 lost requests
"""

import tempfile

import jax.numpy as jnp

from repro.cluster import (
    ArtifactStore,
    ControlPlane,
    EdgeCluster,
    ModelWatcher,
    NodeFailed,
    NodeJoined,
    ServingLoop,
)
from repro.core.model_zoo import demo_mlp
from repro.core.simulate import expand_cluster, random_cluster

# --- a real model: an 8-layer tanh-MLP executed with jax, weights keyed by
# model version so a VersionBumped redeploy visibly changes the function
D = 32
graph, executor_for_version = demo_mlp(d=D)
capacity = graph.total_param_bytes / 3  # each node holds ~1/3 of the model

# --- bootstrap: Sec 2.1 init + Sec 2.2 configuration, in one call ------------
comm, positions = random_cluster(8, capacity, seed=3, with_positions=True)
cluster = EdgeCluster(comm, flops_per_s=1e9)
store = ArtifactStore(tempfile.mkdtemp(prefix="seifer-"))
control = ControlPlane(
    cluster, store, lambda v: graph, executor_for_version,
    capacity=capacity, compression_ratio=2.0, seed=0,  # int8 boundaries
)
control.bootstrap(0)
obs = control.observed()
print(f"bootstrap: {len(obs.path)} partitions on nodes {list(obs.path)}, "
      f"bottleneck {obs.bottleneck_latency*1e3:.3f} ms")

# --- inference step (Sec 2.3): request stream through the admission queue ----
loop = ServingLoop(control, microbatch=4)
for _ in range(8):
    loop.submit(jnp.ones((D,)) * 0.1)
loop.drain()
y0 = loop.completed[0].result
print(f"served {len(loop.completed)} requests, clock {loop.clock_s*1e3:.3f} ms")

# --- node failure: the reconciler re-places partitions on healthy nodes ------
victim = control.pipeline.pods[1].node_id
control.submit(NodeFailed(victim))
actions = control.reconcile()
print(f"NodeFailed({victim}) -> {[(a.kind, a.detail) for a in actions]}")
loop.submit(jnp.ones((D,)) * 0.1)
loop.drain()
identical = bool(jnp.allclose(y0, loop.completed[-1].result))
assert identical, "recovered pipeline must compute identically"
print(f"recovered: path {list(control.observed().path)}, outputs identical: {identical}")

# --- model-version update: watch container emits, reconciler redeploys -------
watcher = ModelWatcher(store)
store.publish(1)  # the external model repository pushes v1
watcher.poll_events(control)
actions = control.reconcile()
print(f"VersionBumped(1) -> {[(a.kind, a.detail) for a in actions]}")
assert control.generation == 0
print("generation still 0 (no cluster restart on a version bump)")

# --- node join: per the paper this is the one event needing a full restart ---
grown, positions = expand_cluster(positions, capacity, seed=11)
control.submit(NodeJoined(comm=grown))
actions = control.reconcile()
print(f"NodeJoined(new node {cluster.n - 1}) -> "
      f"{[(a.kind, a.detail) for a in actions]}")

obs = control.observed()
print(f"lifecycle complete: v{obs.version}, generation {obs.generation}, "
      f"{len(loop.failed)} lost requests")
