"""End-to-end SEIFER lifecycle: init -> probe -> partition/place -> deploy ->
serve -> node failure -> recover -> model-version update -> redeploy.

    PYTHONPATH=src python examples/edge_serving_failover.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import ArtifactStore, Dispatcher, EdgeCluster, ModelWatcher
from repro.core.graph import chain
from repro.core.simulate import random_cluster

# --- a real model: 8-layer MLP executed with jax ---------------------------
D, LAYERS = 32, 8
ws = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (LAYERS, D, D)) * 0.3)


def executor(start, stop, x):
    for i in range(start, stop):  # partition [start, stop) == ws rows
        x = jnp.tanh(x @ ws[i])
    return x


graph = chain("mlp8", [(D * D * 4, 16 * D * 4)] * LAYERS, in_bytes=16 * D * 4)

# --- system initialization (Sec 2.1) ----------------------------------------
cluster = EdgeCluster(random_cluster(8, graph.total_param_bytes / 3, seed=3),
                      flops_per_s=1e9)
store = ArtifactStore(tempfile.mkdtemp(prefix="seifer-"))
disp = Dispatcher(cluster, store, n_classes=4, seed=0)
print(f"leader elected: node {disp.elect_leader()}")
disp.probe_bandwidths()

# --- configuration step (Sec 2.2) -------------------------------------------
plan = disp.configure(graph, version=0, capacity=graph.total_param_bytes / 3)
print(f"plan: {plan.partition.n_parts} partitions on nodes {plan.placement.path}, "
      f"bottleneck {plan.placement.bottleneck_latency*1e3:.3f} ms")
pipe = disp.deploy(plan, executor, compression_ratio=2.0)  # int8 boundaries

# --- inference step (Sec 2.3) -----------------------------------------------
x = jnp.ones((4, D)) * 0.1
y, trace = pipe.run(x)
print(f"inference ok; period {trace.period_s*1e3:.3f} ms "
      f"({1/trace.period_s:.0f} inf/s steady-state)")

# --- node failure + recovery -------------------------------------------------
victim = pipe.pods[1].node_id
print(f"\nkilling node {victim} (hosts partition 1)...")
cluster.fail(victim)
pipe.mark_node_failed(victim)
pipe = disp.recover(pipe, graph, version=0)
y2, _ = pipe.run(x)
assert bool(jnp.allclose(y, y2)), "recovered pipeline must compute identically"
print(f"recovered: new path {pipe.path()}, outputs identical: True")

# --- model-version update (watch container) ----------------------------------
store.publish(0)
watcher = ModelWatcher(store, disp, graph_for_version=lambda v: graph)
store.publish(1)  # external repo pushes v1
pipe = watcher.poll(pipe, executor)
print(f"\nmodel watch: redeployed at version {watcher.deployed_version}, "
      f"path {pipe.path()}")
print("lifecycle complete.")
