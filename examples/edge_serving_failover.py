"""End-to-end SEIFER lifecycle through the ``deploy(spec)`` facade.

One ``DeploymentSpec`` replaces the old six-object hand-wiring: ``deploy()``
bootstraps (elect -> probe -> partition/place -> deploy), then the
``Deployment`` serves a request stream, absorbs a node failure mid-stream
(reconcile: re-place), an in-place model-version update, a strategy swap on
the LIVE deployment (``replan``), and a node join (full cluster restart) --
every convergence step driven by typed events.

    PYTHONPATH=src python examples/edge_serving_failover.py

Expected output (paths/latencies vary slightly with placement seeds):

    bootstrap: 4 partitions on nodes [4, 3, 5, 2], bottleneck 0.159 ms
    served 8 requests, clock 0.107 ms
    NodeFailed(3) -> [('replace', 're-placed 1 pod(s) off node 3')]
    recovered: path [2, 5, 6, 1], outputs identical: True
    VersionBumped(1) -> [('redeploy', 'in-place redeploy at v1')]
    generation still 0 (no cluster restart on a version bump)
    replan(placer='greedy') -> path [...], still v1, generation 0
    NodeJoined(new node 9) -> [('restart', 'full restart (gen 1) ...')]
    lifecycle complete: v1, generation 1, 0 lost requests
"""

import jax.numpy as jnp

from repro.api import ClusterSpec, DeploymentSpec, deploy
from repro.cluster import NodeFailed
from repro.core.model_zoo import demo_mlp

# --- the spec: an executable 8-layer tanh-MLP (weights keyed by model
# version, so a VersionBumped redeploy visibly changes the function) on a
# seeded random wireless cluster, int8 boundary compression ------------------
D = 32
graph, executor_for_version = demo_mlp(d=D)
spec = DeploymentSpec(
    model=graph,  # "demo_mlp" (zoo name) works too and brings its own executor
    executor_for_version=executor_for_version,
    cluster=ClusterSpec(
        n_nodes=8, capacity_bytes=graph.total_param_bytes / 3, seed=3,
    ),
    compression_ratio=2.0,  # int8 boundaries
    seed=0,
    microbatch=4,
)

# --- bootstrap: Sec 2.1 init + Sec 2.2 configuration, in one call ------------
d = deploy(spec)
obs = d.observed()
print(f"bootstrap: {len(obs.path)} partitions on nodes {list(obs.path)}, "
      f"bottleneck {obs.bottleneck_latency*1e3:.3f} ms")

# --- inference step (Sec 2.3): request stream through the admission queue ----
for _ in range(8):
    d.submit(jnp.ones((D,)) * 0.1)
d.drain()
y0 = d.loop.completed[0].result
print(f"served {len(d.loop.completed)} requests, clock {d.loop.clock_s*1e3:.3f} ms")

# --- node failure: the reconciler re-places partitions on healthy nodes ------
victim = d.control.pipeline.pods[1].node_id
d.inject(NodeFailed(victim))
actions = d.reconcile()
print(f"NodeFailed({victim}) -> {[(a.kind, a.detail) for a in actions]}")
d.submit(jnp.ones((D,)) * 0.1)
d.drain()
identical = bool(jnp.allclose(y0, d.loop.completed[-1].result))
assert identical, "recovered pipeline must compute identically"
print(f"recovered: path {list(d.observed().path)}, outputs identical: {identical}")

# --- model-version update: watch container emits, reconciler redeploys -------
d.store.publish(1)  # the external model repository pushes v1
d.poll_model_updates()
actions = d.reconcile()
print(f"VersionBumped(1) -> {[(a.kind, a.detail) for a in actions]}")
assert d.control.generation == 0
print("generation still 0 (no cluster restart on a version bump)")

# --- strategy swap on the LIVE deployment: replan, no restart ----------------
plan = d.replan(placer="greedy")
obs = d.observed()
print(f"replan(placer='greedy') -> path {list(obs.path)}, "
      f"still v{obs.version}, generation {obs.generation}")
assert dict(plan.strategies)["placer"] == "greedy"

# --- node join: per the paper this is the one event needing a full restart ---
d.grow_cluster(seed=11)
actions = d.reconcile()
print(f"NodeJoined(new node {d.cluster.n - 1}) -> "
      f"{[(a.kind, a.detail) for a in actions]}")

obs = d.observed()
print(f"lifecycle complete: v{obs.version}, generation {obs.generation}, "
      f"{len(d.loop.failed)} lost requests")
