"""Serve a small LM with batched requests: prefill + decode loop.

The paper's kind is inference serving; this drives the real serve_step
(KV caches, GQA attention, argmax sampling) for a reduced llama3.2 config.

    PYTHONPATH=src python examples/serve_lm.py [--arch llama3.2-1b] [--tokens 24]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models import lm
from repro.runtime.serve import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch], layers=4, d_model=256, vocab=4096)
    print(f"serving {cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model})")
    params = lm.init_params(cfg, jax.random.PRNGKey(0), max_pos=256)

    b, max_len = args.batch, 128
    caches = lm.init_caches(cfg, b, max_len, enc_len=16)
    step = jax.jit(make_serve_step(cfg, enc_len=16))

    # "prefill" a short prompt token-by-token (engine-level prefill fills
    # caches in one pass; see runtime/serve.py and the dry-run prefill cells)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, 4), 0, cfg.vocab_size)
    tok = prompt[:, :1]
    for i in range(prompt.shape[1]):
        tok, caches = step(params, caches, prompt[:, i : i + 1])

    t0 = time.perf_counter()
    generated = []
    for _ in range(args.tokens):
        tok, caches = step(params, caches, tok)
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"generated {args.tokens} tokens x batch {b} in {dt:.2f}s "
          f"({args.tokens * b / dt:.1f} tok/s on CPU)")
    print("sample:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
