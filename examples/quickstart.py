"""Quickstart: declare a deployment spec, compile it into a plan.

The declarative API in one screen: describe the model, the cluster, and the
strategies by NAME (``repro.api.list_strategies`` shows what's registered),
then let the ``Planner`` run SEIFER's two steps -- min-bottleneck
partitioning (Sec. 2.2-1b) and bandwidth-aware placement (Sec. 2.2-1c) --
and score the result.  No cluster machinery; for serving + churn see
``examples/edge_serving_failover.py`` (the ``deploy()`` facade).

    PYTHONPATH=src python examples/quickstart.py

Expected output (exact numbers vary with the cluster seed):

    registered strategies:
      partitioner: min_bottleneck*, exact_k, exhaustive, min_sum, paper_greedy
      placer: color_coding*, greedy, optimal, random
      joint: sequential*, joint
    model: resnet50, 18 layers, 25.5 MB int8 weights
    partitions: 4, cuts at (12, 14, 15), max boundary 0.80 MB
    placement: nodes (2, 3, 5, 1), bottleneck 47.05 ms, throughput 21.3 inf/s
    compression 1x: period 1059.40 ms, effective throughput 0.9 inf/s
    compression 2x: period 1059.40 ms, effective throughput 0.9 inf/s

(The 2x row matches 1x here because this cluster's period is compute-bound;
on a bandwidth-bound cluster, compression halves the period -- see
``benchmarks/fig3_bottleneck.py``.)
"""

from repro.api import (
    ClusterSpec,
    DeploymentSpec,
    Planner,
    default_strategy,
    list_strategies,
)
from repro.core import evaluate_pipeline
from repro.core.model_zoo import resnet50

# 0. every algorithm is a named, registered strategy (default marked *)
print("registered strategies:")
for kind in ("partitioner", "placer", "joint"):
    names = [n + "*" if n == default_strategy(kind) else n
             for n in list_strategies(kind)]
    print(f"  {kind}: {', '.join(names)}")

# 1. the spec: model + cluster + strategy names, declared up front
graph = resnet50()
capacity = graph.total_param_bytes / 3  # each node holds ~1/3 of the model
spec = DeploymentSpec(
    model="resnet50",  # zoo name; a LayerGraph works too
    cluster=ClusterSpec(n_nodes=8, capacity_bytes=capacity, seed=0),
    partitioner="min_bottleneck",  # SEIFER step 1 (Sec. 2.2-1b)
    placer="color_coding",         # SEIFER step 2 (Sec. 2.2-1c)
)
print(f"model: {graph.name}, {len(graph)} layers, "
      f"{graph.total_param_bytes/1e6:.1f} MB int8 weights")

# 2. compile: validate the spec, partition, place, predict metrics
plan = Planner.from_spec(spec).compile(spec)
part, place = plan.partition, plan.placement
print(f"partitions: {part.n_parts}, cuts at {part.cuts}, "
      f"max boundary {part.max_cut_bytes/1e6:.2f} MB")
print(f"placement: nodes {place.path}, "
      f"bottleneck {place.bottleneck_latency*1e3:.2f} ms, "
      f"throughput {place.throughput:.1f} inf/s")

# 3. end-to-end metrics, with and without boundary compression (ZFP/LZ4
#    on the edge; blockwise int8 on TPU -- see kernels/quantize)
comm, _ = spec.cluster.build()
for ratio in (1.0, 2.0):
    m = evaluate_pipeline(part.partitions, place.path, comm,
                          device_flops=5e9, compression_ratio=ratio)
    print(f"compression {ratio:.0f}x: period {m.pipeline_period*1e3:.2f} ms, "
          f"effective throughput {m.effective_throughput:.1f} inf/s")
