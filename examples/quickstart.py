"""Quickstart: partition a DNN and place it on a simulated edge cluster.

The two core SEIFER algorithms in isolation, no cluster machinery: cut a
ResNet-50 layer graph into min-bottleneck partitions under a per-node
memory cap (Sec. 2.2-1b), then place the partitions so the heaviest
boundary rides the fastest wireless link (Sec. 2.2-1c), and score the
resulting pipeline with and without boundary compression.

    PYTHONPATH=src python examples/quickstart.py

Expected output (exact numbers vary with the cluster seed):

    model: resnet50, 18 layers, 25.5 MB int8 weights
    partitions: 4, cuts at (12, 14, 15), max boundary 0.80 MB
    placement: nodes (2, 3, 5, 1), bottleneck 47.05 ms, throughput 21.3 inf/s
    compression 1x: period 1059.40 ms, effective throughput 0.9 inf/s
    compression 2x: period 1059.40 ms, effective throughput 0.9 inf/s

(The 2x row matches 1x here because this cluster's period is compute-bound;
on a bandwidth-bound cluster, compression halves the period -- see
``benchmarks/fig3_bottleneck.py``.)
"""

import numpy as np

from repro.core import evaluate_pipeline, partition_min_bottleneck, place_color_coding
from repro.core.model_zoo import resnet50
from repro.core.simulate import random_cluster

# 1. the model, as a layer graph (params bytes / activation bytes / flops)
graph = resnet50()
print(f"model: {graph.name}, {len(graph)} layers, "
      f"{graph.total_param_bytes/1e6:.1f} MB int8 weights")

# 2. a cluster: 8 edge nodes + dispatcher, WiFi bandwidths from positions
capacity = graph.total_param_bytes / 3  # each node holds ~1/3 of the model
comm = random_cluster(n_nodes=8, capacity_bytes=capacity, seed=0)

# 3. SEIFER step 1 -- partition: min-bottleneck cuts under node memory
part = partition_min_bottleneck(graph, int(capacity))
print(f"partitions: {part.n_parts}, cuts at {part.cuts}, "
      f"max boundary {part.max_cut_bytes/1e6:.2f} MB")

# 4. SEIFER step 2 -- placement: heaviest boundaries on fastest links
place = place_color_coding(
    part.boundaries, [p.param_bytes for p in part.partitions], comm,
    n_classes=4, dispatcher=0, in_bytes=graph.in_bytes,
)
print(f"placement: nodes {place.path}, "
      f"bottleneck {place.bottleneck_latency*1e3:.2f} ms, "
      f"throughput {place.throughput:.1f} inf/s")

# 5. end-to-end metrics, with and without boundary compression (ZFP/LZ4
#    on the edge; blockwise int8 on TPU -- see kernels/quantize)
for ratio in (1.0, 2.0):
    m = evaluate_pipeline(part.partitions, place.path, comm,
                          device_flops=5e9, compression_ratio=ratio)
    print(f"compression {ratio:.0f}x: period {m.pipeline_period*1e3:.2f} ms, "
          f"effective throughput {m.effective_throughput:.1f} inf/s")
